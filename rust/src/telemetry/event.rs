//! The typed trace event vocabulary and its JSONL (de)serialization.
//!
//! Each event serializes to exactly one JSON object per line with an
//! `"ev"` discriminator field; [`Event::parse`] is the exact inverse of
//! [`Event::to_json_line`] (pinned by the schema-roundtrip tests), so a
//! trace file can be re-read into typed events by `report` or by any
//! external consumer.

use crate::matrix::store::StoreStats;
use crate::util::json::{self, Json};

use super::Counters;

/// What kind of solver pass a [`Event::PassStart`] opens.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PassKind {
    /// A full pass visiting every metric constraint (the classic
    /// Dykstra schedule; also every pass of the non-active drivers).
    Full,
    /// A cheap active-set pass visiting only retained constraints.
    Cheap,
    /// A discovery-sweep pass (screen-then-project over everything).
    Sweep,
}

impl PassKind {
    /// The wire spelling used in the JSONL stream.
    pub fn as_str(self) -> &'static str {
        match self {
            PassKind::Full => "full",
            PassKind::Cheap => "cheap",
            PassKind::Sweep => "sweep",
        }
    }

    /// Inverse of [`Self::as_str`].
    pub fn parse(s: &str) -> Option<PassKind> {
        match s {
            "full" => Some(PassKind::Full),
            "cheap" => Some(PassKind::Cheap),
            "sweep" => Some(PassKind::Sweep),
            _ => None,
        }
    }
}

/// Which solver phase a [`Event::Phase`] measured.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhaseName {
    /// The metric (triangle-constraint) projection phase.
    Metric,
    /// The CC-LP pair (`[0,1]`-box + pair slack) phase.
    Pair,
    /// An exact residual scan (violation / gap measurement).
    ResidualScan,
    /// A discovery sweep (screen + project).
    Sweep,
    /// Checkpoint capture and sink invocation.
    Checkpoint,
    /// A proximal normal-equations solve (CG matvec sweeps).
    Cg,
}

impl PhaseName {
    /// The wire spelling used in the JSONL stream.
    pub fn as_str(self) -> &'static str {
        match self {
            PhaseName::Metric => "metric",
            PhaseName::Pair => "pair",
            PhaseName::ResidualScan => "residual-scan",
            PhaseName::Sweep => "sweep",
            PhaseName::Checkpoint => "checkpoint",
            PhaseName::Cg => "cg",
        }
    }

    /// Inverse of [`Self::as_str`].
    pub fn parse(s: &str) -> Option<PhaseName> {
        match s {
            "metric" => Some(PhaseName::Metric),
            "pair" => Some(PhaseName::Pair),
            "residual-scan" => Some(PhaseName::ResidualScan),
            "sweep" => Some(PhaseName::Sweep),
            "checkpoint" => Some(PhaseName::Checkpoint),
            "cg" => Some(PhaseName::Cg),
            _ => None,
        }
    }
}

/// One structured trace event. Passes are numbered from 1 in the trace
/// (matching the CLI's human-facing output), and cumulative counters
/// (`triplet_visits`, store I/O) are monotone snapshots, so consumers
/// can difference adjacent passes.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// A solver pass begins.
    PassStart {
        /// 1-based pass number.
        pass: u64,
        /// What kind of pass this is.
        kind: PassKind,
    },
    /// One timed phase within a pass.
    Phase {
        /// 1-based pass number.
        pass: u64,
        /// Which phase was measured.
        name: PhaseName,
        /// Wall seconds for the phase (driver-side, includes barriers).
        secs: f64,
        /// Constraint visits performed by the phase (0 when the phase
        /// does not visit constraints, e.g. checkpointing).
        visits: u64,
        /// Per-worker busy seconds (tile/chunk work, excluding barrier
        /// waits); empty when the phase ran without worker timing
        /// (serial / XLA drivers, residual scans).
        workers: Vec<f64>,
    },
    /// A discovery sweep's screen/project outcome.
    Sweep {
        /// 1-based pass number.
        pass: u64,
        /// Constraints screened by the vectorized violation check.
        screened: u64,
        /// Constraints that survived the screen and were projected.
        projected: u64,
        /// Maximum violation observed by the sweep.
        max_violation: f64,
    },
    /// Active-set dynamics after a pass (active strategies only).
    ActiveSet {
        /// 1-based pass number.
        pass: u64,
        /// Triplets retained in the active set after the pass.
        size: u64,
        /// Triplets dropped by the retention policy this pass.
        forgotten: u64,
    },
    /// A residual measurement (the convergence timeline).
    Residuals {
        /// 1-based pass number.
        pass: u64,
        /// Maximum metric-constraint violation.
        max_violation: f64,
        /// Relative duality gap (0 for nearness solves).
        rel_gap: f64,
        /// LP objective value (0 for nearness solves).
        lp_objective: f64,
        /// True for an exact scan; false for a sweep-trusted estimate.
        exact: bool,
    },
    /// A cumulative tile-store I/O snapshot (disk-backed solves only).
    StoreIo {
        /// 1-based pass number.
        pass: u64,
        /// Cumulative cache counters at the end of the pass.
        stats: StoreStats,
    },
    /// A solver pass ends.
    PassEnd {
        /// 1-based pass number.
        pass: u64,
        /// Wall seconds for the whole pass.
        secs: f64,
        /// Cumulative triplet visits at the end of the pass.
        triplet_visits: u64,
        /// Active triplets after the pass (the full constraint count for
        /// non-active strategies).
        active_triplets: u64,
    },
    /// Tile-store operations were retried this pass (fault injection or
    /// a genuinely flaky device); the solve healed without unwinding.
    StoreRetry {
        /// 1-based pass number.
        pass: u64,
        /// Retries drained this pass (not cumulative).
        retries: u64,
        /// A compact sample of what was retried, e.g.
        /// `"x/read block 3 attempt 1: I/O error"`.
        detail: String,
    },
    /// The recovery harness resumed a failed solve from its last
    /// periodic checkpoint.
    Recovery {
        /// 1-based recovery attempt number.
        attempt: u64,
        /// Pass the reloaded checkpoint resumes from.
        pass: u64,
        /// The store failure that forced the resume.
        msg: String,
    },
    /// A non-fatal notice (fallbacks, skipped work).
    Warn {
        /// Human-readable message.
        msg: String,
    },
    /// End-of-solve summary: the unified counter snapshot.
    Footer {
        /// Final counters for the whole solve.
        counters: Counters,
    },
}

impl Event {
    /// Serialize as one JSON line (no trailing newline).
    pub fn to_json_line(&self) -> String {
        self.to_json().to_string()
    }

    fn to_json(&self) -> Json {
        let obj = |ev: &str, mut fields: Vec<(String, Json)>| {
            fields.insert(0, ("ev".to_string(), Json::Str(ev.to_string())));
            Json::Obj(fields)
        };
        let f = |k: &str, v: Json| (k.to_string(), v);
        match self {
            Event::PassStart { pass, kind } => obj(
                "pass_start",
                vec![
                    f("pass", json::unum(*pass)),
                    f("kind", Json::Str(kind.as_str().to_string())),
                ],
            ),
            Event::Phase { pass, name, secs, visits, workers } => obj(
                "phase",
                vec![
                    f("pass", json::unum(*pass)),
                    f("name", Json::Str(name.as_str().to_string())),
                    f("secs", json::num(*secs)),
                    f("visits", json::unum(*visits)),
                    f(
                        "workers",
                        Json::Arr(workers.iter().map(|w| json::num(*w)).collect()),
                    ),
                ],
            ),
            Event::Sweep { pass, screened, projected, max_violation } => obj(
                "sweep",
                vec![
                    f("pass", json::unum(*pass)),
                    f("screened", json::unum(*screened)),
                    f("projected", json::unum(*projected)),
                    f("max_violation", json::num(*max_violation)),
                ],
            ),
            Event::ActiveSet { pass, size, forgotten } => obj(
                "active_set",
                vec![
                    f("pass", json::unum(*pass)),
                    f("size", json::unum(*size)),
                    f("forgotten", json::unum(*forgotten)),
                ],
            ),
            Event::Residuals { pass, max_violation, rel_gap, lp_objective, exact } => obj(
                "residuals",
                vec![
                    f("pass", json::unum(*pass)),
                    f("max_violation", json::num(*max_violation)),
                    f("rel_gap", json::num(*rel_gap)),
                    f("lp_objective", json::num(*lp_objective)),
                    f("exact", Json::Bool(*exact)),
                ],
            ),
            Event::StoreIo { pass, stats } => {
                let mut fields = vec![f("pass", json::unum(*pass))];
                fields.extend(store_stats_fields(stats));
                obj("store_io", fields)
            }
            Event::PassEnd { pass, secs, triplet_visits, active_triplets } => obj(
                "pass_end",
                vec![
                    f("pass", json::unum(*pass)),
                    f("secs", json::num(*secs)),
                    f("triplet_visits", json::unum(*triplet_visits)),
                    f("active_triplets", json::unum(*active_triplets)),
                ],
            ),
            Event::StoreRetry { pass, retries, detail } => obj(
                "store_retry",
                vec![
                    f("pass", json::unum(*pass)),
                    f("retries", json::unum(*retries)),
                    f("detail", Json::Str(detail.clone())),
                ],
            ),
            Event::Recovery { attempt, pass, msg } => obj(
                "recovery",
                vec![
                    f("attempt", json::unum(*attempt)),
                    f("pass", json::unum(*pass)),
                    f("msg", Json::Str(msg.clone())),
                ],
            ),
            Event::Warn { msg } => obj("warn", vec![f("msg", Json::Str(msg.clone()))]),
            Event::Footer { counters } => {
                obj("footer", counters.to_json_fields())
            }
        }
    }

    /// Parse one JSONL trace line back into a typed event.
    pub fn parse(line: &str) -> Result<Event, String> {
        let v = Json::parse(line)?;
        let ev = v
            .get("ev")
            .and_then(Json::as_str)
            .ok_or_else(|| "missing `ev` discriminator".to_string())?;
        let pass = || {
            v.get("pass")
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("{ev}: missing `pass`"))
        };
        let num = |k: &str| {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("{ev}: missing `{k}`"))
        };
        let unum = |k: &str| {
            v.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("{ev}: missing `{k}`"))
        };
        let text = |k: &str| {
            v.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| format!("{ev}: missing `{k}`"))
        };
        match ev {
            "pass_start" => Ok(Event::PassStart {
                pass: pass()?,
                kind: PassKind::parse(text("kind")?)
                    .ok_or_else(|| format!("bad pass kind `{}`", text("kind").unwrap()))?,
            }),
            "phase" => Ok(Event::Phase {
                pass: pass()?,
                name: PhaseName::parse(text("name")?)
                    .ok_or_else(|| format!("bad phase name `{}`", text("name").unwrap()))?,
                secs: num("secs")?,
                visits: unum("visits")?,
                workers: v
                    .get("workers")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| "phase: missing `workers`".to_string())?
                    .iter()
                    .map(|w| w.as_f64().ok_or_else(|| "bad worker seconds".to_string()))
                    .collect::<Result<Vec<f64>, String>>()?,
            }),
            "sweep" => Ok(Event::Sweep {
                pass: pass()?,
                screened: unum("screened")?,
                projected: unum("projected")?,
                max_violation: num("max_violation")?,
            }),
            "active_set" => Ok(Event::ActiveSet {
                pass: pass()?,
                size: unum("size")?,
                forgotten: unum("forgotten")?,
            }),
            "residuals" => Ok(Event::Residuals {
                pass: pass()?,
                max_violation: num("max_violation")?,
                rel_gap: num("rel_gap")?,
                lp_objective: num("lp_objective")?,
                exact: v
                    .get("exact")
                    .and_then(Json::as_bool)
                    .ok_or_else(|| "residuals: missing `exact`".to_string())?,
            }),
            "store_io" => Ok(Event::StoreIo {
                pass: pass()?,
                stats: parse_store_stats(&v).map_err(|k| format!("store_io: missing `{k}`"))?,
            }),
            "pass_end" => Ok(Event::PassEnd {
                pass: pass()?,
                secs: num("secs")?,
                triplet_visits: unum("triplet_visits")?,
                active_triplets: unum("active_triplets")?,
            }),
            "store_retry" => Ok(Event::StoreRetry {
                pass: pass()?,
                retries: unum("retries")?,
                detail: text("detail")?.to_string(),
            }),
            "recovery" => Ok(Event::Recovery {
                attempt: unum("attempt")?,
                pass: pass()?,
                msg: text("msg")?.to_string(),
            }),
            "warn" => Ok(Event::Warn { msg: text("msg")?.to_string() }),
            "footer" => Ok(Event::Footer { counters: Counters::from_json(&v)? }),
            other => Err(format!("unknown event `{other}`")),
        }
    }
}

/// Serialize [`StoreStats`] as flat object fields (shared by the
/// `store_io` event and the footer's `store` sub-object).
pub(crate) fn store_stats_fields(stats: &StoreStats) -> Vec<(String, Json)> {
    let f = |k: &str, v: u64| (k.to_string(), json::unum(v));
    vec![
        f("loads", stats.loads),
        f("evictions", stats.evictions),
        f("writebacks", stats.writebacks),
        f("prefetched", stats.prefetched),
        f("peak_resident_bytes", stats.peak_resident_bytes),
        f("w_loads", stats.w_loads),
        f("w_evictions", stats.w_evictions),
        f("entry_loads", stats.entry_loads),
        f("blocks_skipped", stats.blocks_skipped),
        f("retries", stats.retries),
        f("shard_requests", stats.shard_requests),
        f("shard_bytes_in", stats.shard_bytes_in),
        f("shard_bytes_out", stats.shard_bytes_out),
        f("barrier_wait_us", stats.barrier_wait_us),
    ]
}

/// Inverse of [`store_stats_fields`]; `Err` carries the missing key.
/// The entry-lease and retry counters default to 0 when absent so
/// traces recorded before they existed keep parsing.
pub(crate) fn parse_store_stats(v: &Json) -> Result<StoreStats, &'static str> {
    let get = |k: &'static str| v.get(k).and_then(Json::as_u64).ok_or(k);
    let opt = |k: &'static str| v.get(k).and_then(Json::as_u64).unwrap_or(0);
    Ok(StoreStats {
        loads: get("loads")?,
        evictions: get("evictions")?,
        writebacks: get("writebacks")?,
        prefetched: get("prefetched")?,
        w_loads: get("w_loads")?,
        w_evictions: get("w_evictions")?,
        peak_resident_bytes: get("peak_resident_bytes")?,
        entry_loads: opt("entry_loads"),
        blocks_skipped: opt("blocks_skipped"),
        retries: opt("retries"),
        shard_requests: opt("shard_requests"),
        shard_bytes_in: opt("shard_bytes_in"),
        shard_bytes_out: opt("shard_bytes_out"),
        barrier_wait_us: opt("barrier_wait_us"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<Event> {
        vec![
            Event::PassStart { pass: 1, kind: PassKind::Sweep },
            Event::Phase {
                pass: 1,
                name: PhaseName::Metric,
                secs: 0.125,
                visits: 455,
                workers: vec![0.0625, 0.03125],
            },
            Event::Phase {
                pass: 1,
                name: PhaseName::ResidualScan,
                secs: 0.5,
                visits: 455,
                workers: vec![],
            },
            Event::Phase {
                pass: 3,
                name: PhaseName::Cg,
                secs: 0.0625,
                visits: 910,
                workers: vec![],
            },
            Event::Sweep { pass: 1, screened: 455, projected: 20, max_violation: 0.75 },
            Event::ActiveSet { pass: 2, size: 20, forgotten: 3 },
            Event::Residuals {
                pass: 2,
                max_violation: 0.25,
                rel_gap: 0.0078125,
                lp_objective: 12.5,
                exact: true,
            },
            Event::StoreIo {
                pass: 2,
                stats: StoreStats {
                    loads: 10,
                    evictions: 4,
                    writebacks: 2,
                    prefetched: 6,
                    peak_resident_bytes: 65536,
                    w_loads: 3,
                    w_evictions: 1,
                    entry_loads: 12,
                    blocks_skipped: 5,
                    retries: 7,
                    shard_requests: 40,
                    shard_bytes_in: 8192,
                    shard_bytes_out: 4096,
                    barrier_wait_us: 150,
                },
            },
            Event::PassEnd { pass: 2, secs: 0.25, triplet_visits: 910, active_triplets: 20 },
            Event::StoreRetry {
                pass: 2,
                retries: 3,
                detail: "x/read block 3 attempt 1: \"I/O\" error".to_string(),
            },
            Event::Recovery {
                attempt: 1,
                pass: 2,
                msg: "store failure: I/O error".to_string(),
            },
            Event::Warn { msg: "engine \"fallback\"\nsecond line".to_string() },
            Event::Footer {
                counters: Counters {
                    passes: 2,
                    metric_visits: 2730,
                    active_triplets: 20,
                    sweep_screened: 455,
                    sweep_projected: 20,
                    nnz_duals: 17,
                    max_violation: 0.25,
                    rel_gap: 0.0078125,
                    phase_secs: vec![("metric".to_string(), 0.625)],
                    worker_busy_secs: vec![("metric".to_string(), 0.09375)],
                    store: Some(StoreStats { loads: 10, ..StoreStats::default() }),
                },
            },
        ]
    }

    #[test]
    fn every_variant_roundtrips_typed() {
        for ev in sample_events() {
            let line = ev.to_json_line();
            let back = Event::parse(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(back, ev, "{line}");
        }
    }

    #[test]
    fn every_variant_roundtrips_textually() {
        for ev in sample_events() {
            let line = ev.to_json_line();
            assert!(!line.contains('\n'), "one line per event: {line}");
            let reline = Event::parse(&line).unwrap().to_json_line();
            assert_eq!(reline, line);
        }
    }

    #[test]
    fn footer_without_store_roundtrips() {
        let ev = Event::Footer { counters: Counters::default() };
        assert_eq!(Event::parse(&ev.to_json_line()).unwrap(), ev);
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        assert!(Event::parse("{}").is_err());
        assert!(Event::parse(r#"{"ev":"nope"}"#).is_err());
        assert!(Event::parse(r#"{"ev":"pass_start","pass":1,"kind":"weird"}"#).is_err());
        assert!(Event::parse(r#"{"ev":"sweep","pass":1}"#).is_err());
        assert!(Event::parse("not json").is_err());
    }
}
