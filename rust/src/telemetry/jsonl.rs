//! The JSONL trace writer.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::Context as _;

use super::{Event, Recorder};

/// Writes one JSON object per line to a trace file.
///
/// Events are buffered and flushed on [`JsonlRecorder::finish`] (or on
/// drop, best-effort). I/O errors are latched — recording never panics
/// mid-solve — and surfaced by `finish`, so a solve completes even if
/// the trace disk fills up.
pub struct JsonlRecorder {
    path: PathBuf,
    inner: Mutex<Inner>,
}

struct Inner {
    out: BufWriter<File>,
    err: Option<std::io::Error>,
}

impl JsonlRecorder {
    /// Create (truncate) the trace file at `path`.
    pub fn create(path: &Path) -> anyhow::Result<Self> {
        let file = File::create(path)
            .with_context(|| format!("creating trace file {}", path.display()))?;
        Ok(JsonlRecorder {
            path: path.to_path_buf(),
            inner: Mutex::new(Inner { out: BufWriter::new(file), err: None }),
        })
    }

    /// The trace file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Flush and close, surfacing the first I/O error hit while
    /// recording (if any).
    pub fn finish(self) -> anyhow::Result<()> {
        let path = self.path.clone();
        let mut inner = self.inner.into_inner().unwrap_or_else(|e| e.into_inner());
        let latched = inner.err.take();
        let flush = inner.out.flush();
        if let Some(e) = latched {
            return Err(anyhow::Error::from(e)
                .context(format!("writing trace file {}", path.display())));
        }
        flush.with_context(|| format!("flushing trace file {}", path.display()))
    }
}

impl Recorder for JsonlRecorder {
    fn record(&self, ev: &Event) {
        let mut inner = self.inner.lock().unwrap();
        if inner.err.is_some() {
            return;
        }
        let mut line = ev.to_json_line();
        line.push('\n');
        if let Err(e) = inner.out.write_all(line.as_bytes()) {
            inner.err = Some(e);
        }
    }
}

impl Drop for JsonlRecorder {
    fn drop(&mut self) {
        if let Ok(mut inner) = self.inner.lock() {
            let _ = inner.out.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_path(tag: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("metric_proj_jsonl_{tag}_{}.jsonl", std::process::id()))
    }

    #[test]
    fn writes_one_line_per_event() {
        let path = temp_path("basic");
        let rec = JsonlRecorder::create(&path).unwrap();
        assert!(rec.enabled());
        rec.record(&Event::Warn { msg: "a".to_string() });
        rec.record(&Event::PassStart { pass: 1, kind: super::super::PassKind::Full });
        rec.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            Event::parse(line).unwrap();
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn create_fails_on_bad_path() {
        let bad = Path::new("/nonexistent-dir-for-sure/trace.jsonl");
        assert!(JsonlRecorder::create(bad).is_err());
    }
}
