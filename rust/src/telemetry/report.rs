//! Trace-file summarization for the CLI `report` subcommand.
//!
//! Reads one or more JSONL traces written by [`super::JsonlRecorder`],
//! folds the events into a [`TraceSummary`], and renders a fixed-width
//! phase-time / convergence table (documented with a worked example in
//! `docs/OBSERVABILITY.md`).

use std::fmt::Write as _;

use anyhow::Context as _;

use super::{Counters, Event, PassKind};

/// Aggregates derived from one trace file.
#[derive(Clone, Debug, Default)]
pub struct TraceSummary {
    /// Passes seen (count of `pass_end` events).
    pub passes: u64,
    /// Full / cheap / sweep pass counts, from `pass_start` kinds.
    pub pass_kinds: [u64; 3],
    /// Total wall seconds across passes (sum of `pass_end` secs).
    pub total_secs: f64,
    /// Per-phase `(name, wall secs, busy secs, visits)` in first-seen
    /// order, folded over every `phase` event.
    pub phases: Vec<(String, f64, f64, u64)>,
    /// `(pass, max_violation, rel_gap, exact)` timeline.
    pub residuals: Vec<(u64, f64, f64, bool)>,
    /// Cumulative screened / projected constraints over all sweeps.
    pub sweeps: (u64, u64),
    /// `(last size, peak size, total forgotten)` of the active set.
    pub active: Option<(u64, u64, u64)>,
    /// Final cumulative triplet visits.
    pub triplet_visits: u64,
    /// Last store I/O snapshot, if the solve was disk-backed.
    pub store: Option<crate::matrix::store::StoreStats>,
    /// Total store-operation retries drained across all passes.
    pub store_retries: u64,
    /// Recovery resumes seen (count of `recovery` events).
    pub recoveries: u64,
    /// Warn messages, in order.
    pub warns: Vec<String>,
    /// The footer counters, when the trace has one.
    pub footer: Option<Counters>,
}

impl TraceSummary {
    /// Fold a stream of events into a summary.
    pub fn from_events(events: &[Event]) -> TraceSummary {
        let mut s = TraceSummary::default();
        for ev in events {
            match ev {
                Event::PassStart { kind, .. } => {
                    let slot = match kind {
                        PassKind::Full => 0,
                        PassKind::Cheap => 1,
                        PassKind::Sweep => 2,
                    };
                    s.pass_kinds[slot] += 1;
                }
                Event::Phase { name, secs, visits, workers, .. } => {
                    let busy: f64 = workers.iter().sum();
                    let key = name.as_str();
                    if let Some(slot) = s.phases.iter_mut().find(|(n, ..)| n == key) {
                        slot.1 += secs;
                        slot.2 += busy;
                        slot.3 += visits;
                    } else {
                        s.phases.push((key.to_string(), *secs, busy, *visits));
                    }
                }
                Event::Sweep { screened, projected, .. } => {
                    s.sweeps.0 += screened;
                    s.sweeps.1 += projected;
                }
                Event::ActiveSet { size, forgotten, .. } => {
                    let entry = s.active.get_or_insert((0, 0, 0));
                    entry.0 = *size;
                    entry.1 = entry.1.max(*size);
                    entry.2 += forgotten;
                }
                Event::Residuals { pass, max_violation, rel_gap, exact, .. } => {
                    s.residuals.push((*pass, *max_violation, *rel_gap, *exact));
                }
                Event::StoreIo { stats, .. } => s.store = Some(*stats),
                Event::PassEnd { secs, triplet_visits, .. } => {
                    s.passes += 1;
                    s.total_secs += secs;
                    s.triplet_visits = *triplet_visits;
                }
                Event::StoreRetry { retries, .. } => s.store_retries += retries,
                Event::Recovery { .. } => s.recoveries += 1,
                Event::Warn { msg } => s.warns.push(msg.clone()),
                Event::Footer { counters } => s.footer = Some(counters.clone()),
            }
        }
        s
    }
}

/// Read a trace file into typed events, failing with the offending line
/// number on schema errors.
pub fn read_trace(path: &str) -> anyhow::Result<Vec<Event>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace file {path}"))?;
    let mut events = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let ev = Event::parse(line)
            .map_err(|e| anyhow::anyhow!("{path}:{}: {e}", lineno + 1))?;
        events.push(ev);
    }
    Ok(events)
}

/// Render the summary table for one trace.
pub fn render(path: &str, summary: &TraceSummary) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "trace {path}");
    let [full, cheap, sweep] = summary.pass_kinds;
    let _ = writeln!(
        out,
        "  passes    : {} ({} full, {} cheap, {} sweep) in {:.3}s wall",
        summary.passes, full, cheap, sweep, summary.total_secs
    );
    let metric_visits = summary
        .footer
        .as_ref()
        .map(|c| c.metric_visits)
        .unwrap_or(summary.triplet_visits * 3);
    if summary.total_secs > 0.0 {
        let _ = writeln!(
            out,
            "  work      : {} metric visits ({:.3e} visits/s)",
            metric_visits,
            metric_visits as f64 / summary.total_secs
        );
    } else {
        let _ = writeln!(out, "  work      : {metric_visits} metric visits");
    }
    if !summary.phases.is_empty() {
        let phase_total: f64 = summary.phases.iter().map(|(_, w, ..)| w).sum();
        let _ = writeln!(out, "  phase           wall      share    busy      visits");
        for (name, wall, busy, visits) in &summary.phases {
            let share = if phase_total > 0.0 { wall / phase_total * 100.0 } else { 0.0 };
            let busy_text =
                if *busy > 0.0 { format!("{busy:8.3}s") } else { "       –".to_string() };
            let _ = writeln!(
                out,
                "    {name:<13} {wall:8.3}s  {share:5.1}%  {busy_text}  {visits:>10}"
            );
        }
    }
    if summary.sweeps.0 > 0 {
        let (screened, projected) = summary.sweeps;
        let _ = writeln!(
            out,
            "  sweeps    : {} screened, {} projected ({:.2}% hit rate)",
            screened,
            projected,
            projected as f64 / screened as f64 * 100.0
        );
    }
    if let Some((last, peak, forgotten)) = summary.active {
        let _ = writeln!(
            out,
            "  active set: {last} at exit (peak {peak}), {forgotten} forgotten"
        );
    }
    if !summary.residuals.is_empty() {
        let _ = writeln!(out, "  convergence (pass, max violation, rel gap):");
        // First point, up to four most recent points.
        let n = summary.residuals.len();
        let mut shown: Vec<usize> = if n <= 5 {
            (0..n).collect()
        } else {
            let mut idx = vec![0usize];
            idx.extend(n - 4..n);
            idx
        };
        shown.dedup();
        let mut elided = false;
        for (i, &r) in shown.iter().enumerate() {
            if i > 0 && r > shown[i - 1] + 1 && !elided {
                let _ = writeln!(out, "    ...");
                elided = true;
            }
            let (pass, viol, gap, exact) = summary.residuals[r];
            let tag = if exact { "" } else { "  (sweep estimate)" };
            let _ = writeln!(out, "    {pass:>6}  {viol:11.4e}  {gap:11.4e}{tag}");
        }
    }
    if let Some(stats) = &summary.store {
        let _ = writeln!(
            out,
            "  store io  : {} loads, {} evictions, {} writebacks, {} prefetched, {} W-loads, peak {:.1} MiB",
            stats.loads,
            stats.evictions,
            stats.writebacks,
            stats.prefetched,
            stats.w_loads,
            stats.peak_resident_bytes as f64 / (1024.0 * 1024.0)
        );
        if stats.entry_loads > 0 {
            let _ = writeln!(
                out,
                "  entry io  : {} entries via entry leases, {} footprint blocks skipped",
                stats.entry_loads, stats.blocks_skipped
            );
        }
        if stats.shard_requests > 0 {
            let _ = writeln!(
                out,
                "  shard io  : {} requests, {:.1} MiB out, {:.1} MiB in, {:.1} ms barrier wait",
                stats.shard_requests,
                stats.shard_bytes_out as f64 / (1024.0 * 1024.0),
                stats.shard_bytes_in as f64 / (1024.0 * 1024.0),
                stats.barrier_wait_us as f64 / 1000.0
            );
        }
    }
    if summary.store_retries > 0 || summary.recoveries > 0 {
        let _ = writeln!(
            out,
            "  resilience: {} store retries, {} checkpoint recoveries",
            summary.store_retries, summary.recoveries
        );
    }
    if let Some(c) = &summary.footer {
        let _ = writeln!(
            out,
            "  final     : viol {:.4e}, gap {:.4e}, {} active, {} nnz duals",
            c.max_violation, c.rel_gap, c.active_triplets, c.nnz_duals
        );
    }
    for msg in &summary.warns {
        let _ = writeln!(out, "  warn      : {msg}");
    }
    out
}

/// Read and render one or more trace files (the `report` subcommand
/// body). Output concatenates one table per file.
pub fn render_files(paths: &[&str]) -> anyhow::Result<String> {
    let mut out = String::new();
    for (i, path) in paths.iter().enumerate() {
        if i > 0 {
            out.push('\n');
        }
        let events = read_trace(path)?;
        let summary = TraceSummary::from_events(&events);
        out.push_str(&render(path, &summary));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{PassKind, PhaseName};

    fn sample() -> Vec<Event> {
        vec![
            Event::PassStart { pass: 1, kind: PassKind::Sweep },
            Event::Phase {
                pass: 1,
                name: PhaseName::Sweep,
                secs: 0.5,
                visits: 100,
                workers: vec![0.25, 0.2],
            },
            Event::Sweep { pass: 1, screened: 100, projected: 25, max_violation: 1.0 },
            Event::Residuals {
                pass: 1,
                max_violation: 1.0,
                rel_gap: 0.5,
                lp_objective: 3.0,
                exact: false,
            },
            Event::PassEnd { pass: 1, secs: 0.6, triplet_visits: 100, active_triplets: 25 },
            Event::PassStart { pass: 2, kind: PassKind::Cheap },
            Event::Phase {
                pass: 2,
                name: PhaseName::Metric,
                secs: 0.1,
                visits: 25,
                workers: vec![],
            },
            Event::ActiveSet { pass: 2, size: 20, forgotten: 5 },
            Event::Residuals {
                pass: 2,
                max_violation: 0.25,
                rel_gap: 0.125,
                lp_objective: 3.5,
                exact: true,
            },
            Event::StoreRetry {
                pass: 2,
                retries: 3,
                detail: "x/read block 1 attempt 1: I/O error".to_string(),
            },
            Event::Recovery { attempt: 1, pass: 1, msg: "store failure".to_string() },
            Event::PassEnd { pass: 2, secs: 0.2, triplet_visits: 125, active_triplets: 20 },
        ]
    }

    #[test]
    fn summary_folds_events() {
        let s = TraceSummary::from_events(&sample());
        assert_eq!(s.passes, 2);
        assert_eq!(s.pass_kinds, [0, 1, 1]);
        assert!((s.total_secs - 0.8).abs() < 1e-12);
        assert_eq!(s.sweeps, (100, 25));
        assert_eq!(s.active, Some((20, 20, 5)));
        assert_eq!(s.residuals.len(), 2);
        assert_eq!(s.triplet_visits, 125);
        let sweep_phase = s.phases.iter().find(|(n, ..)| n == "sweep").unwrap();
        assert!((sweep_phase.2 - 0.45).abs() < 1e-12);
        assert_eq!(s.store_retries, 3);
        assert_eq!(s.recoveries, 1);
    }

    #[test]
    fn render_mentions_key_sections() {
        let s = TraceSummary::from_events(&sample());
        let text = render("trace.jsonl", &s);
        for needle in
            ["passes", "sweep", "active set", "convergence", "hit rate", "resilience"]
        {
            assert!(text.contains(needle), "missing `{needle}` in:\n{text}");
        }
        let quiet = TraceSummary::default();
        assert!(
            !render("t", &quiet).contains("resilience"),
            "retry line only appears when something was retried"
        );
    }

    #[test]
    fn read_trace_roundtrip_via_file() {
        let path = std::env::temp_dir()
            .join(format!("metric_proj_report_{}.jsonl", std::process::id()));
        let mut text = String::new();
        for ev in sample() {
            text.push_str(&ev.to_json_line());
            text.push('\n');
        }
        std::fs::write(&path, text).unwrap();
        let events = read_trace(path.to_str().unwrap()).unwrap();
        assert_eq!(events, sample());
        let rendered = render_files(&[path.to_str().unwrap()]).unwrap();
        assert!(rendered.contains("trace "));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn read_trace_reports_line_numbers() {
        let path = std::env::temp_dir()
            .join(format!("metric_proj_report_bad_{}.jsonl", std::process::id()));
        std::fs::write(&path, "{\"ev\":\"warn\",\"msg\":\"ok\"}\nnot json\n").unwrap();
        let err = read_trace(path.to_str().unwrap()).unwrap_err();
        assert!(err.to_string().contains(":2:"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
