//! Structured solver telemetry: trace events, recorders, and the
//! unified counter snapshot.
//!
//! Every driver (`dykstra_serial`, `dykstra_parallel`, the active-set
//! drivers, `nearness`, `dykstra_xla`) has a `*_traced` entry point
//! taking a [`Recorder`]; the plain entry points delegate with
//! [`NullRecorder`]. All instrumentation is gated on
//! [`Recorder::enabled`], so a null-recorded solve does no extra work —
//! no timestamps, no allocation — and is pinned bitwise identical to an
//! untraced one (`tests/telemetry.rs`).
//!
//! The moving parts:
//! * [`event::Event`] — the typed event vocabulary, one JSON object per
//!   line on the wire (schema in `docs/OBSERVABILITY.md`).
//! * [`JsonlRecorder`] — appends events to a line-delimited trace file.
//! * [`ProgressRecorder`] — renders a one-line stderr progress report
//!   per pass (the CLI's `--progress`).
//! * [`Counters`] — the end-of-solve snapshot unifying the previously
//!   scattered fields (`metric_visits`, `sweep_*`, `StoreStats`);
//!   surfaced by `Solution::counters()` / `NearnessSolution::counters()`
//!   and serialized as the trace footer.
//! * [`warn`] — the library-wide notice channel: routed to the global
//!   recorder when one is installed, else to stderr only when
//!   `METRIC_PROJ_LOG` is set, so library code never prints
//!   unconditionally.

pub mod event;
mod jsonl;
pub mod report;

use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::matrix::store::StoreStats;
use crate::util::json::{self, Json};
use crate::util::shared::PerWorker;
use crate::util::timer::PhaseTimer;

pub use event::{Event, PassKind, PhaseName};
pub use jsonl::JsonlRecorder;

/// A sink for trace events.
///
/// Implementations must be cheap to call from the driver thread between
/// phases (they are never called from inside the hot loops) and
/// thread-safe: a recorder may be shared by a solve and the global
/// [`warn`] channel simultaneously.
pub trait Recorder: Send + Sync {
    /// Whether this recorder wants events. Drivers skip all
    /// instrumentation — including timestamps — when this is false, so
    /// it must be constant for the lifetime of a solve.
    fn enabled(&self) -> bool {
        true
    }

    /// Consume one event.
    fn record(&self, ev: &Event);
}

/// The default recorder: discards everything and reports itself
/// disabled, so traced drivers behave exactly like untraced ones.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullRecorder;

impl Recorder for NullRecorder {
    fn enabled(&self) -> bool {
        false
    }

    fn record(&self, _ev: &Event) {}
}

/// Fan events out to several recorders (e.g. a trace file plus the
/// stderr progress line). Disabled members are skipped; the tee is
/// enabled iff any member is.
pub struct Tee<'a> {
    recs: Vec<&'a dyn Recorder>,
}

impl<'a> Tee<'a> {
    /// Combine `recs`; an empty list yields a disabled recorder.
    pub fn new(recs: Vec<&'a dyn Recorder>) -> Self {
        Tee { recs }
    }
}

impl Recorder for Tee<'_> {
    fn enabled(&self) -> bool {
        self.recs.iter().any(|r| r.enabled())
    }

    fn record(&self, ev: &Event) {
        for r in &self.recs {
            if r.enabled() {
                r.record(ev);
            }
        }
    }
}

/// Unified end-of-solve counter snapshot.
///
/// Collects the work and convergence counters that were previously
/// scattered across `Solution` fields and `StoreStats` into one type;
/// the traced drivers serialize it as the trace footer
/// ([`Event::Footer`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Counters {
    /// Passes executed.
    pub passes: u64,
    /// Scalar metric-constraint visits (3 per triplet visit).
    pub metric_visits: u64,
    /// Active triplets at termination (full constraint count for
    /// non-active strategies).
    pub active_triplets: u64,
    /// Constraints screened by discovery sweeps.
    pub sweep_screened: u64,
    /// Screened constraints that were actually projected.
    pub sweep_projected: u64,
    /// Nonzero dual variables at termination.
    pub nnz_duals: u64,
    /// Final maximum constraint violation.
    pub max_violation: f64,
    /// Final relative duality gap (0 for nearness solves).
    pub rel_gap: f64,
    /// Per-phase wall seconds, driver-side (empty for untraced solves).
    pub phase_secs: Vec<(String, f64)>,
    /// Per-phase busy seconds summed over workers (empty when no
    /// per-worker timing was collected).
    pub worker_busy_secs: Vec<(String, f64)>,
    /// Cumulative tile-store I/O (disk-backed solves only).
    pub store: Option<StoreStats>,
}

impl Counters {
    /// Fraction of screened constraints that needed projection, if any
    /// sweep ran.
    pub fn screen_hit_rate(&self) -> Option<f64> {
        if self.sweep_screened > 0 {
            Some(self.sweep_projected as f64 / self.sweep_screened as f64)
        } else {
            None
        }
    }

    pub(crate) fn to_json_fields(&self) -> Vec<(String, Json)> {
        let phases = |pairs: &[(String, f64)]| {
            Json::Arr(
                pairs
                    .iter()
                    .map(|(n, s)| Json::Arr(vec![Json::Str(n.clone()), json::num(*s)]))
                    .collect(),
            )
        };
        let f = |k: &str, v: Json| (k.to_string(), v);
        vec![
            f("passes", json::unum(self.passes)),
            f("metric_visits", json::unum(self.metric_visits)),
            f("active_triplets", json::unum(self.active_triplets)),
            f("sweep_screened", json::unum(self.sweep_screened)),
            f("sweep_projected", json::unum(self.sweep_projected)),
            f("nnz_duals", json::unum(self.nnz_duals)),
            f("max_violation", json::num(self.max_violation)),
            f("rel_gap", json::num(self.rel_gap)),
            f("phase_secs", phases(&self.phase_secs)),
            f("worker_busy_secs", phases(&self.worker_busy_secs)),
            f(
                "store",
                match &self.store {
                    Some(stats) => Json::Obj(event::store_stats_fields(stats)),
                    None => Json::Null,
                },
            ),
        ]
    }

    pub(crate) fn from_json(v: &Json) -> Result<Counters, String> {
        let unum = |k: &str| {
            v.get(k).and_then(Json::as_u64).ok_or_else(|| format!("footer: missing `{k}`"))
        };
        let num = |k: &str| {
            v.get(k).and_then(Json::as_f64).ok_or_else(|| format!("footer: missing `{k}`"))
        };
        let phases = |k: &str| -> Result<Vec<(String, f64)>, String> {
            v.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("footer: missing `{k}`"))?
                .iter()
                .map(|pair| {
                    let pair = pair.as_arr().filter(|a| a.len() == 2);
                    let name = pair.and_then(|a| a[0].as_str());
                    let secs = pair.and_then(|a| a[1].as_f64());
                    match (name, secs) {
                        (Some(n), Some(s)) => Ok((n.to_string(), s)),
                        _ => Err(format!("footer: bad `{k}` entry")),
                    }
                })
                .collect()
        };
        let store = match v.get("store") {
            None | Some(Json::Null) => None,
            Some(obj) => Some(
                event::parse_store_stats(obj)
                    .map_err(|k| format!("footer store: missing `{k}`"))?,
            ),
        };
        Ok(Counters {
            passes: unum("passes")?,
            metric_visits: unum("metric_visits")?,
            active_triplets: unum("active_triplets")?,
            sweep_screened: unum("sweep_screened")?,
            sweep_projected: unum("sweep_projected")?,
            nnz_duals: unum("nnz_duals")?,
            max_violation: num("max_violation")?,
            rel_gap: num("rel_gap")?,
            phase_secs: phases("phase_secs")?,
            worker_busy_secs: phases("worker_busy_secs")?,
            store,
        })
    }
}

/// Streams a one-line progress report to stderr after every pass: pass
/// number, latest max violation and gap, active triplets, and the
/// pass's metric-visit throughput. Composes with [`JsonlRecorder`] via
/// [`Tee`].
#[derive(Debug, Default)]
pub struct ProgressRecorder {
    state: Mutex<ProgressState>,
}

#[derive(Debug, Default)]
struct ProgressState {
    residuals: Option<(f64, f64)>,
    last_visits: u64,
}

impl ProgressRecorder {
    /// A fresh progress reporter (call once per solve).
    pub fn new() -> Self {
        Self::default()
    }
}

impl Recorder for ProgressRecorder {
    fn record(&self, ev: &Event) {
        let mut st = self.state.lock().unwrap();
        match ev {
            Event::Residuals { max_violation, rel_gap, .. } => {
                st.residuals = Some((*max_violation, *rel_gap));
            }
            Event::PassEnd { pass, secs, triplet_visits, active_triplets } => {
                let delta = triplet_visits.saturating_sub(st.last_visits);
                st.last_visits = *triplet_visits;
                let vps = if *secs > 0.0 { (delta * 3) as f64 / secs } else { 0.0 };
                let (viol, gap) = match st.residuals {
                    Some((v, g)) => (format!("{v:9.3e}"), format!("{g:9.3e}")),
                    None => ("        –".to_string(), "        –".to_string()),
                };
                eprintln!(
                    "pass {pass:>4}  viol {viol}  gap {gap}  active {active_triplets:>10}  visits/s {vps:9.3e}"
                );
            }
            _ => {}
        }
    }
}

static GLOBAL: OnceLock<Box<dyn Recorder>> = OnceLock::new();

/// Install the process-wide recorder used by [`warn`]. First caller
/// wins; later calls are ignored (the CLI installs once at startup).
pub fn set_global(rec: Box<dyn Recorder>) {
    let _ = GLOBAL.set(rec);
}

/// Emit a non-fatal library notice.
///
/// Routed to the global recorder when one is installed and enabled;
/// otherwise printed to stderr only if the `METRIC_PROJ_LOG`
/// environment variable is set. Library code must use this instead of
/// `eprintln!` so embedding applications stay silent by default.
pub fn warn(msg: &str) {
    if let Some(rec) = GLOBAL.get() {
        if rec.enabled() {
            rec.record(&Event::Warn { msg: msg.to_string() });
            return;
        }
    }
    if std::env::var_os("METRIC_PROJ_LOG").is_some() {
        eprintln!("warn: {msg}");
    }
}

/// Driver-side phase instrumentation helper.
///
/// Owns the master wall-clock [`PhaseTimer`] plus one busy-seconds
/// timer per worker; drivers bracket each phase with
/// [`PhaseProbe::start`] / [`PhaseProbe::finish`]. Everything is a
/// no-op (and allocation-free) when the recorder is disabled.
pub(crate) struct PhaseProbe<'a> {
    rec: &'a dyn Recorder,
    p: usize,
    wall: PhaseTimer,
    busy: Vec<PhaseTimer>,
}

impl<'a> PhaseProbe<'a> {
    /// A probe for a solve with `p` workers recording into `rec`.
    pub fn new(rec: &'a dyn Recorder, p: usize) -> Self {
        let workers = if rec.enabled() { p } else { 0 };
        PhaseProbe { rec, p, wall: PhaseTimer::new(), busy: vec![PhaseTimer::new(); workers] }
    }

    /// Whether instrumentation is live.
    #[inline]
    pub fn on(&self) -> bool {
        self.rec.enabled()
    }

    /// Begin timing a phase (`None` when disabled — pass it straight to
    /// [`Self::finish`]).
    #[inline]
    pub fn start(&self) -> Option<Instant> {
        if self.on() {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Fresh per-worker busy-seconds accumulators for one phase, when
    /// instrumentation is live. Hand the reference to the timed phase
    /// function and return the value to [`Self::finish`].
    pub fn workers(&self) -> Option<PerWorker<f64>> {
        if self.on() {
            Some(PerWorker::new(vec![0.0f64; self.p]))
        } else {
            None
        }
    }

    /// Close a phase: accumulate wall and per-worker busy time, emit the
    /// [`Event::Phase`]. No-op when `t0` is `None`.
    pub fn finish(
        &mut self,
        pass: u64,
        name: PhaseName,
        t0: Option<Instant>,
        visits: u64,
        workers: Option<PerWorker<f64>>,
    ) {
        let Some(t0) = t0 else { return };
        let secs = t0.elapsed().as_secs_f64();
        self.wall.add(name.as_str(), secs);
        let worker_secs = workers.map(PerWorker::into_inner).unwrap_or_default();
        for (tid, s) in worker_secs.iter().enumerate() {
            self.busy[tid].add(name.as_str(), *s);
        }
        self.rec.record(&Event::Phase { pass, name, secs, visits, workers: worker_secs });
    }

    /// Pass an event through to the recorder (when enabled).
    #[inline]
    pub fn emit(&self, ev: Event) {
        if self.on() {
            self.rec.record(&ev);
        }
    }

    /// The accumulated per-phase wall seconds.
    pub fn wall_totals(&self) -> Vec<(String, f64)> {
        self.wall.phases().to_vec()
    }

    /// Per-phase busy seconds, reduced over workers with
    /// [`PhaseTimer::absorb`].
    pub fn busy_totals(&self) -> Vec<(String, f64)> {
        let mut merged = PhaseTimer::new();
        for t in &self.busy {
            merged.absorb(t);
        }
        merged.phases().to_vec()
    }
}

/// Add a worker's elapsed busy time into its slot.
///
/// # Safety
/// Caller must be worker `tid` with exclusive use of slot `tid` (the
/// same contract as [`PerWorker::get_mut`]).
#[inline]
pub(crate) unsafe fn add_busy(acc: Option<&PerWorker<f64>>, tid: usize, t0: Option<Instant>) {
    if let (Some(acc), Some(t0)) = (acc, t0) {
        *acc.get_mut(tid) += t0.elapsed().as_secs_f64();
    }
}

/// Start a busy-time measurement iff an accumulator is attached.
#[inline]
pub(crate) fn busy_start(acc: Option<&PerWorker<f64>>) -> Option<Instant> {
    acc.map(|_| Instant::now())
}

#[cfg(test)]
mod tests {
    use super::*;

    struct VecRecorder(Mutex<Vec<Event>>);

    impl Recorder for VecRecorder {
        fn record(&self, ev: &Event) {
            self.0.lock().unwrap().push(ev.clone());
        }
    }

    #[test]
    fn null_recorder_is_disabled() {
        assert!(!NullRecorder.enabled());
    }

    #[test]
    fn tee_fans_out_and_skips_disabled() {
        let sink = VecRecorder(Mutex::new(Vec::new()));
        let null = NullRecorder;
        let tee = Tee::new(vec![&null, &sink]);
        assert!(tee.enabled());
        tee.record(&Event::Warn { msg: "x".to_string() });
        assert_eq!(sink.0.lock().unwrap().len(), 1);
        assert!(!Tee::new(vec![&null]).enabled());
        assert!(!Tee::new(vec![]).enabled());
    }

    #[test]
    fn probe_disabled_is_inert() {
        let mut probe = PhaseProbe::new(&NullRecorder, 4);
        assert!(!probe.on());
        assert!(probe.start().is_none());
        assert!(probe.workers().is_none());
        probe.finish(1, PhaseName::Metric, None, 10, None);
        assert!(probe.wall_totals().is_empty());
        assert!(probe.busy_totals().is_empty());
    }

    #[test]
    fn probe_accumulates_and_emits() {
        let sink = VecRecorder(Mutex::new(Vec::new()));
        let mut probe = PhaseProbe::new(&sink, 2);
        let t0 = probe.start();
        let ws = probe.workers();
        if let Some(ws) = &ws {
            unsafe {
                *ws.get_mut(0) += 0.5;
                *ws.get_mut(1) += 0.25;
            }
        }
        probe.finish(1, PhaseName::Metric, t0, 42, ws);
        let t1 = probe.start();
        probe.finish(2, PhaseName::Metric, t1, 7, None);
        let events = sink.0.lock().unwrap();
        assert_eq!(events.len(), 2);
        match &events[0] {
            Event::Phase { pass: 1, name: PhaseName::Metric, visits: 42, workers, .. } => {
                assert_eq!(workers, &vec![0.5, 0.25]);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Wall totals accumulate across both finishes; busy totals only
        // saw the first (merged via PhaseTimer::absorb).
        assert_eq!(probe.wall_totals().len(), 1);
        let busy = probe.busy_totals();
        assert_eq!(busy.len(), 1);
        assert!((busy[0].1 - 0.75).abs() < 1e-12);
    }

    #[test]
    fn counters_hit_rate() {
        let mut c = Counters::default();
        assert_eq!(c.screen_hit_rate(), None);
        c.sweep_screened = 100;
        c.sweep_projected = 25;
        assert_eq!(c.screen_hit_rate(), Some(0.25));
    }
}
