//! # metric-proj — Parallel Projection Methods for Metric-Constrained Optimization
//!
//! A production-quality reproduction of *"A Parallel Projection Method for
//! Metric Constrained Optimization"* (Ruggles, Veldt, Gleich, 2019): a
//! memory-efficient parallel Dykstra solver for optimization problems with
//! `O(n^3)` triangle-inequality constraints, applied to the LP relaxation
//! of correlation clustering and to metric nearness.
//!
//! Architecture (three layers, Python never on the solve path):
//! * **L3 (this crate)** — the paper's contribution: a conflict-free
//!   parallel execution schedule over metric constraints
//!   ([`solver::schedule`]), tiled for cache efficiency
//!   ([`solver::tiling`]), with per-thread sparse dual storage
//!   ([`solver::duals`]), plus every substrate: graphs, instances,
//!   rounding, evaluation.
//! * **Active-set layer** ([`solver::active`]) — project-and-forget on
//!   top of the wave schedule: cheap passes visit only the constraints
//!   that recently mattered (nonzero duals), full discovery sweeps every
//!   few passes re-measure everything, and a retention policy forgets
//!   persistently idle constraints. Selected per solve via
//!   [`solver::SolveOpts::strategy`]; cuts constraint visits by large
//!   factors once duals sparsify, without changing the fixed point.
//!   Discovery sweeps themselves run on the screen-then-project engine
//!   ([`solver::active::sweep`]): a branch-free vectorized violation
//!   screen per contiguous `k`-run, scalar projection of the compact
//!   worklist, bitwise identical to the classic sweep and selectable
//!   per solve via [`solver::SolveOpts::sweep_backend`] (with a PJRT
//!   batch variant), on a fixed or adaptive cadence
//!   ([`solver::SolveOpts::sweep_policy`]).
//! * **Storage layer** ([`matrix::store`]) — the packed distance matrix
//!   behind a [`matrix::store::TileStore`]: the resident array
//!   ([`matrix::store::MemStore`], free pass-through leases) or an
//!   out-of-core [`matrix::store::DiskStore`] that streams `(i, k)`
//!   tile blocks from disk under a bounded LRU working set with
//!   write-back and sweep-order prefetch, plus a second read-only plane
//!   streaming the inverse weights. Tile leases carry the metric
//!   phases; pair-range leases
//!   ([`matrix::store::TileStore::with_pair_range`]) carry the CC-LP
//!   pair phase and the residual scans — so both `solve` and `nearness`
//!   run at `n` beyond RAM, bitwise identical to the resident path, and
//!   checkpoints reference the store file instead of re-serializing
//!   `x`.
//! * **L2/L1 (build time)** — a JAX model + Pallas kernel implementing the
//!   batched projection step, AOT-lowered to HLO text and executed from
//!   Rust through PJRT ([`runtime`]).
//!
//! `README.md` maps the crate layout; `docs/ARCHITECTURE.md` documents
//! the solver data flow, the load-bearing `visit_triplet` no-op
//! contract, and the checkpoint / tile-store binary formats.

pub mod cli;
pub mod eval;
pub mod graph;
pub mod instance;
pub mod matrix;
pub mod rounding;
pub mod runtime;
pub mod solver;
pub mod telemetry;
pub mod util;
