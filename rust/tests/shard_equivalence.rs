//! Multi-process sharding acceptance tests: a solve whose packed `x` /
//! `winv` planes are partitioned across shard workers behind the
//! coordinator↔worker socket protocol must be **bitwise identical** to
//! the resident in-memory solve — for 1, 2, and 4 workers, the full and
//! active strategies, and the nearness and CC-LP drivers alike. The
//! shard files double as checkpoint v2's external-`x` referent, and the
//! partition-independent FNV chain means a checkpoint written by a
//! 2-worker run resumes bitwise under 1 or 4 workers.
//!
//! Worker transport: the in-library tests run in-process thread workers
//! (same protocol and framing, no fork cost) across the wide case
//! matrix, plus one real-process case via `CARGO_BIN_EXE_metric-proj`.
//! The subprocess tests at the bottom SIGKILL a worker process mid-run
//! (`tests/kill_resume.rs` style): the coordinator's per-pass barrier
//! heartbeat must turn the dead socket into a typed store failure naming
//! the last-good checkpoint, and `--recover-attempts` must respawn the
//! workers and land bitwise on the uninterrupted reference.

use metric_proj::instance::metric_nearness::MetricNearnessInstance;
use metric_proj::instance::CcLpInstance;
use metric_proj::matrix::store::StoreCfg;
use metric_proj::solver::checkpoint::SolverState;
use metric_proj::solver::nearness::{self, NearnessOpts, NearnessSolution};
use metric_proj::solver::{dykstra_parallel, Solution, SolveOpts, Strategy};
use metric_proj::util::parallel::env_threads;
use std::path::PathBuf;

const BIN: &str = env!("CARGO_BIN_EXE_metric-proj");

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("metric_proj_shard_eq_{tag}_{}", std::process::id()))
}

fn solve_collecting(
    inst: &MetricNearnessInstance,
    opts: &NearnessOpts,
    cfg: &StoreCfg,
    resume: Option<&SolverState>,
) -> (NearnessSolution, Vec<SolverState>) {
    let mut states = Vec::new();
    let sol = nearness::solve_stored(inst, opts, cfg, resume, &mut |s| states.push(s.clone()))
        .expect("solve_stored");
    (sol, states)
}

fn assert_same_solution(a: &NearnessSolution, b: &NearnessSolution, ctx: &str) {
    assert_eq!(a.x, b.x, "{ctx}: x diverged");
    assert_eq!(a.passes, b.passes, "{ctx}: pass counts diverged");
    assert_eq!(a.metric_visits, b.metric_visits, "{ctx}: work accounting diverged");
    assert_eq!(a.max_violation, b.max_violation, "{ctx}: reported violation diverged");
    assert_eq!(a.objective, b.objective, "{ctx}: objective diverged");
}

fn cc_solve_collecting(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    cfg: &StoreCfg,
    resume: Option<&SolverState>,
) -> (Solution, Vec<SolverState>) {
    let mut states = Vec::new();
    let sol =
        dykstra_parallel::solve_stored(inst, opts, cfg, resume, &mut |s| states.push(s.clone()))
            .expect("solve_stored");
    (sol, states)
}

fn assert_same_cc_solution(a: &Solution, b: &Solution, ctx: &str) {
    assert_eq!(a.x, b.x, "{ctx}: x diverged");
    assert_eq!(a.f, b.f, "{ctx}: slacks diverged");
    assert_eq!(a.passes, b.passes, "{ctx}: pass counts diverged");
    assert_eq!(a.nnz_duals, b.nnz_duals, "{ctx}: dual counts diverged");
    assert_eq!(a.metric_visits, b.metric_visits, "{ctx}: work accounting diverged");
    assert_eq!(
        a.residuals.max_violation, b.residuals.max_violation,
        "{ctx}: reported violation diverged"
    );
    assert_eq!(a.residuals.rel_gap, b.residuals.rel_gap, "{ctx}: gap diverged");
}

/// The shard run's transport counters must prove the leases actually
/// crossed the sockets.
fn assert_shard_traffic(sol_stats: Option<metric_proj::matrix::store::StoreStats>, ctx: &str) {
    let stats = sol_stats.expect("shard solves report store stats");
    assert!(stats.shard_requests > 0, "{ctx}: no lease ever crossed a socket");
    assert!(stats.shard_bytes_out > 0, "{ctx}: no request bytes were counted");
    assert!(stats.shard_bytes_in > 0, "{ctx}: no response bytes were counted");
}

#[test]
fn nearness_shard_and_mem_solves_are_bitwise_identical_across_worker_counts() {
    let cases = [
        // (n, tile, threads, workers, strategy)
        (24usize, 4usize, 1usize, 1usize, Strategy::Full),
        (24, 4, env_threads(3), 2, Strategy::Full),
        (26, 5, env_threads(2), 4, Strategy::Full),
        (30, 7, env_threads(2), 2, Strategy::Active { sweep_every: 3, forget_after: 1 }),
        (34, 5, env_threads(3), 4, Strategy::Active { sweep_every: 4, forget_after: 2 }),
        // tile > n: a single tile still shards column-granularly.
        (19, 40, 2, 2, Strategy::Active { sweep_every: 2, forget_after: 0 }),
    ];
    for (idx, &(n, tile, threads, workers, strategy)) in cases.iter().enumerate() {
        let inst = MetricNearnessInstance::random(n, 2.0, 7 + idx as u64);
        let opts = NearnessOpts {
            max_passes: 12,
            check_every: 4,
            tol_violation: 1e-9,
            threads,
            tile,
            strategy,
            ..Default::default()
        };
        let ctx = format!("case {idx}: n={n} tile={tile} p={threads} w={workers} {strategy:?}");
        let (mem, _) = solve_collecting(&inst, &opts, &StoreCfg::mem(), None);
        assert!(mem.store_stats.is_none(), "{ctx}: mem solves carry no store stats");
        let dir = tmp_dir(&format!("near{idx}"));
        let (shard, _) = solve_collecting(&inst, &opts, &StoreCfg::shard(&dir, workers), None);
        assert_same_solution(&mem, &shard, &ctx);
        assert_shard_traffic(shard.store_stats, &ctx);
        let _ = std::fs::remove_dir_all(dir);
    }
}

#[test]
fn cc_shard_and_mem_solves_are_bitwise_identical_across_worker_counts() {
    // The CC-LP drivers push the metric phases, the pair phase, and the
    // residual scans through the store; weighted instances stream winv
    // from the workers' second plane.
    let cases = [
        // (n, tile, threads, workers, strategy)
        (24usize, 4usize, env_threads(2), 1usize, Strategy::Full),
        (24, 4, env_threads(3), 2, Strategy::Full),
        (26, 5, env_threads(2), 2, Strategy::Active { sweep_every: 3, forget_after: 1 }),
        (28, 6, env_threads(2), 4, Strategy::Active { sweep_every: 3, forget_after: 1 }),
    ];
    for (idx, &(n, tile, threads, workers, strategy)) in cases.iter().enumerate() {
        let inst = CcLpInstance::random(n, 0.5, 0.8, 1.6, 31 + idx as u64);
        let opts = SolveOpts {
            max_passes: 10,
            check_every: 4,
            tol_violation: 1e-12,
            tol_gap: 1e-12,
            threads,
            tile,
            strategy,
            ..Default::default()
        };
        let ctx =
            format!("cc case {idx}: n={n} tile={tile} p={threads} w={workers} {strategy:?}");
        let (mem, _) = cc_solve_collecting(&inst, &opts, &StoreCfg::mem(), None);
        let dir = tmp_dir(&format!("cc{idx}"));
        let (shard, _) =
            cc_solve_collecting(&inst, &opts, &StoreCfg::shard(&dir, workers), None);
        assert_same_cc_solution(&mem, &shard, &ctx);
        assert_shard_traffic(shard.store_stats, &ctx);
        let _ = std::fs::remove_dir_all(dir);
    }
}

#[test]
fn process_workers_and_thread_workers_land_bitwise_identical() {
    // One case through real worker *processes* (the CLI transport): the
    // fork boundary must not change a bit relative to thread workers or
    // the resident solve.
    let n = 24;
    let inst = MetricNearnessInstance::random(n, 2.0, 77);
    let opts = NearnessOpts {
        max_passes: 8,
        check_every: 3,
        tol_violation: 1e-12,
        threads: 2,
        tile: 5,
        strategy: Strategy::Active { sweep_every: 3, forget_after: 1 },
        ..Default::default()
    };
    let (mem, _) = solve_collecting(&inst, &opts, &StoreCfg::mem(), None);
    let dir_t = tmp_dir("proc_vs_thread_t");
    let (threads_sol, _) = solve_collecting(&inst, &opts, &StoreCfg::shard(&dir_t, 2), None);
    let dir_p = tmp_dir("proc_vs_thread_p");
    let mut cfg = StoreCfg::shard(&dir_p, 2);
    cfg.worker_exe = Some(PathBuf::from(BIN));
    let (procs_sol, _) = solve_collecting(&inst, &opts, &cfg, None);
    assert_same_solution(&mem, &threads_sol, "thread workers vs resident");
    assert_same_solution(&mem, &procs_sol, "process workers vs resident");
    assert_shard_traffic(procs_sol.store_stats, "process workers");
    let _ = std::fs::remove_dir_all(dir_t);
    let _ = std::fs::remove_dir_all(dir_p);
}

#[test]
fn shard_checkpoints_reference_the_shards_and_resume_across_worker_counts() {
    // The shard files are checkpoint v2's external-x referent, and the
    // stamp FNV chains shard-by-shard into the packed-plane fingerprint —
    // so a checkpoint stamped by a 2-worker run must resume bitwise under
    // 4 workers (repartition) and under 1 (gather).
    let n = 32;
    let inst = MetricNearnessInstance::random(n, 2.0, 11);
    let strategy = Strategy::Active { sweep_every: 3, forget_after: 1 };
    let base = NearnessOpts {
        check_every: 2,
        tol_violation: 1e-12,
        threads: 2,
        tile: 5,
        strategy,
        checkpoint_every: 2,
        ..Default::default()
    };

    // Uninterrupted references, memory and sharded.
    let full_opts = NearnessOpts { max_passes: 9, ..base };
    let (mem_ref, _) = solve_collecting(&inst, &full_opts, &StoreCfg::mem(), None);
    let dir_ref = tmp_dir("ckpt_ref");
    let (shard_ref, _) =
        solve_collecting(&inst, &full_opts, &StoreCfg::shard(&dir_ref, 2), None);
    assert_same_solution(&mem_ref, &shard_ref, "uninterrupted sharded run");

    // Interrupt a 2-worker run at pass 4: the emitted states must
    // reference the shard files instead of re-serializing x.
    let dir = tmp_dir("ckpt_resume");
    let half_opts = NearnessOpts { max_passes: 4, ..base };
    let (_half, states) = solve_collecting(&inst, &half_opts, &StoreCfg::shard(&dir, 2), None);
    let last = states.last().expect("checkpoints were emitted");
    assert_eq!(last.pass, 4);
    assert!(last.x_external, "shard checkpoints must reference the shard files");
    assert!(last.x.is_empty(), "external checkpoints must not inline x");
    let mut bytes = Vec::new();
    last.save(&mut bytes).expect("save");
    let reloaded = SolverState::load(&mut bytes.as_slice()).expect("load");
    assert_eq!(*last, reloaded);

    // Clone the interrupted store so each worker count resumes from the
    // identical pass-4 shard files.
    let clone_store = |tag: &str| -> PathBuf {
        let dst = tmp_dir(tag);
        let _ = std::fs::remove_dir_all(&dst);
        std::fs::create_dir_all(&dst).expect("mkdir clone");
        for entry in std::fs::read_dir(&dir).expect("read interrupted store") {
            let entry = entry.expect("dir entry");
            std::fs::copy(entry.path(), dst.join(entry.file_name())).expect("copy shard file");
        }
        dst
    };
    for workers in [1usize, 2, 4] {
        let dst = clone_store(&format!("resume_w{workers}"));
        let (resumed, _) = solve_collecting(
            &inst,
            &full_opts,
            &StoreCfg::shard(&dst, workers),
            Some(&reloaded),
        );
        assert_same_solution(
            &mem_ref,
            &resumed,
            &format!("2-worker checkpoint resumed under {workers} worker(s)"),
        );
        let _ = std::fs::remove_dir_all(dst);
    }

    let _ = std::fs::remove_dir_all(dir);
    let _ = std::fs::remove_dir_all(dir_ref);
}

#[test]
fn fresh_shard_solve_refuses_to_overwrite_existing_shards() {
    // Shard files on disk may be the only copy of an earlier run's
    // iterate; a fresh (non-resuming) solve must refuse to clobber them.
    let n = 18;
    let inst = MetricNearnessInstance::random(n, 2.0, 97);
    let opts = NearnessOpts {
        max_passes: 3,
        check_every: 0,
        threads: 1,
        tile: 4,
        strategy: Strategy::Full,
        checkpoint_every: 2,
        ..Default::default()
    };
    let dir = tmp_dir("no_clobber");
    let cfg = StoreCfg::shard(&dir, 2);
    let (_first, _) = solve_collecting(&inst, &opts, &cfg, None);
    let err = nearness::solve_stored(&inst, &opts, &cfg, None, &mut |_| {})
        .expect_err("second fresh solve must refuse the existing shard files");
    assert!(
        format!("{err:?}").contains("refusing to overwrite"),
        "error should explain the refusal: {err:?}"
    );
    let _ = std::fs::remove_dir_all(dir);
}

// ---------------------------------------------------------------------------
// Worker-failure subprocess tests against the real binary.
// ---------------------------------------------------------------------------

#[cfg(unix)]
mod worker_failure {
    use super::{tmp_dir, BIN};
    use metric_proj::solver::checkpoint::SolverState;
    use std::path::Path;
    use std::process::{Child, Command, Stdio};
    use std::time::{Duration, Instant};

    const N: usize = 110;

    /// `nearness` invocation shared by every run of one scenario: same
    /// instance (seed), same schedule, same pass budget, 2 worker
    /// processes.
    fn nearness_cmd(store_dir: &Path, ck: &Path) -> Command {
        let mut cmd = Command::new(BIN);
        cmd.args(["nearness", "--n", &N.to_string(), "--seed", "9"]);
        cmd.args(["--passes", "14", "--threads", "2", "--tile", "16"]);
        cmd.args(["--store", "shard", "--workers", "2"]);
        cmd.arg("--store-dir").arg(store_dir);
        cmd.arg("--checkpoint").arg(ck);
        cmd.args(["--checkpoint-every", "1"]);
        cmd.stdout(Stdio::piped()).stderr(Stdio::piped());
        cmd
    }

    /// Block until `ck` holds a loadable state with `pass >= 1`, or the
    /// victim exits (tolerated: the run degenerates to an uninterrupted
    /// one, which keeps the equality assertions valid).
    fn wait_for_first_checkpoint(ck: &Path, child: &mut Child) -> bool {
        let deadline = Instant::now() + Duration::from_secs(120);
        loop {
            if let Ok(st) = SolverState::load_path(ck) {
                if st.pass >= 1 {
                    return true;
                }
            }
            if let Ok(Some(status)) = child.try_wait() {
                assert!(status.success(), "victim exited early with {status}");
                return false;
            }
            assert!(Instant::now() < deadline, "no checkpoint appeared within 120s");
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// SIGKILL the shard-0 worker process, whose pid the per-shard lock
    /// file records. Returns false when the worker (or its lock) is
    /// already gone — the victim outran us.
    fn kill_shard0_worker(store_dir: &Path, coordinator_pid: u32) -> bool {
        let lock = store_dir.join("x.tiles.shard0.lock");
        let Ok(text) = std::fs::read_to_string(&lock) else { return false };
        let Ok(pid) = text.trim().parse::<u32>() else { return false };
        assert_ne!(
            pid, coordinator_pid,
            "per-shard locks must hold the worker's pid, not the coordinator's"
        );
        Command::new("kill")
            .args(["-9", &pid.to_string()])
            .status()
            .map(|s| s.success())
            .unwrap_or(false)
    }

    fn wait_with_timeout(child: &mut Child, secs: u64) -> std::process::ExitStatus {
        let deadline = Instant::now() + Duration::from_secs(secs);
        loop {
            if let Ok(Some(status)) = child.try_wait() {
                return status;
            }
            if Instant::now() >= deadline {
                let _ = child.kill();
                panic!("subprocess did not exit within {secs}s");
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    fn read_to_string<R: std::io::Read>(h: Option<R>) -> String {
        let mut out = String::new();
        if let Some(mut h) = h {
            let _ = h.read_to_string(&mut out);
        }
        out
    }

    /// The `solution fnv : 0x…` line both CLI drivers print — the
    /// cross-run bitwise pin the nightly shard matrix diffs too.
    fn solution_fnv_line(stdout: &str) -> String {
        stdout
            .lines()
            .find(|l| l.starts_with("solution fnv"))
            .unwrap_or_else(|| panic!("no solution fnv line in:\n{stdout}"))
            .to_string()
    }

    #[test]
    fn worker_sigkill_fails_typed_and_resumes_bitwise() {
        // Without --recover-attempts: a killed worker must surface as a
        // typed store failure naming the last-good checkpoint, and a
        // manual --resume must land bitwise on the uninterrupted
        // reference.
        let root = tmp_dir("wkill");
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(&root).expect("mkdir");
        let (ref_store, ref_ck) = (root.join("ref_store"), root.join("ref.ckpt"));
        let (store, ck) = (root.join("store"), root.join("run.ckpt"));

        // Uninterrupted sharded reference.
        let out = nearness_cmd(&ref_store, &ref_ck).output().expect("spawn reference");
        assert!(
            out.status.success(),
            "reference run failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
        let ref_fnv = solution_fnv_line(&String::from_utf8_lossy(&out.stdout));

        let mut victim = nearness_cmd(&store, &ck).spawn().expect("spawn victim");
        let checkpointed = wait_for_first_checkpoint(&ck, &mut victim);
        let killed = checkpointed && kill_shard0_worker(&store, victim.id());
        let status = wait_with_timeout(&mut victim, 120);
        let stdout = read_to_string(victim.stdout.take());
        let stderr = read_to_string(victim.stderr.take());
        if status.success() {
            // The victim outran the kill (or finished the final pass
            // before the next heartbeat): it degenerates to an
            // uninterrupted run and must still match the reference.
            assert_eq!(solution_fnv_line(&stdout), ref_fnv, "degenerate run diverged");
        } else {
            assert!(killed, "victim failed without a kill:\n{stderr}");
            assert!(
                stderr.contains("store failure"),
                "worker death must surface as a typed store failure:\n{stderr}"
            );
            assert!(
                stderr.contains("last good checkpoint") && stderr.contains("run.ckpt"),
                "the failure must name the last-good checkpoint:\n{stderr}"
            );

            // Manual resume from the named checkpoint: the stale shard-0
            // lock (dead pid) is broken, the shard files reopen at the
            // stamped pass, and the run lands on the reference bitwise.
            let out = nearness_cmd(&store, &ck)
                .arg("--resume")
                .arg(&ck)
                .output()
                .expect("spawn resume");
            assert!(
                out.status.success(),
                "resume after worker SIGKILL failed:\n{}\n{}",
                String::from_utf8_lossy(&out.stdout),
                String::from_utf8_lossy(&out.stderr)
            );
            let stdout = String::from_utf8_lossy(&out.stdout);
            assert!(stdout.contains("resume    : from pass"), "resume banner missing:\n{stdout}");
            assert_eq!(solution_fnv_line(&stdout), ref_fnv, "resumed run diverged");
        }

        // Either way the final checkpoints agree (external stamps and
        // duals included).
        let a = SolverState::load_path(&ref_ck).expect("reference checkpoint loads");
        let b = SolverState::load_path(&ck).expect("recovered checkpoint loads");
        assert_eq!(a, b, "final checkpoint states diverged");
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn worker_sigkill_with_recover_attempts_resumes_in_process() {
        // With --recover-attempts: the coordinator reloads the last
        // checkpoint, respawns the workers (breaking the dead worker's
        // stale per-shard lock), and finishes bitwise — one process, no
        // operator in the loop. The trace records the recovery.
        let root = tmp_dir("wrecover");
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(&root).expect("mkdir");
        let (ref_store, ref_ck) = (root.join("ref_store"), root.join("ref.ckpt"));
        let (store, ck) = (root.join("store"), root.join("run.ckpt"));
        let trace = root.join("trace.jsonl");

        let out = nearness_cmd(&ref_store, &ref_ck).output().expect("spawn reference");
        assert!(
            out.status.success(),
            "reference run failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
        let ref_fnv = solution_fnv_line(&String::from_utf8_lossy(&out.stdout));

        let mut victim = nearness_cmd(&store, &ck)
            .args(["--recover-attempts", "2"])
            .arg("--trace-out")
            .arg(&trace)
            .spawn()
            .expect("spawn victim");
        let checkpointed = wait_for_first_checkpoint(&ck, &mut victim);
        let killed = checkpointed && kill_shard0_worker(&store, victim.id());
        let status = wait_with_timeout(&mut victim, 120);
        let stdout = read_to_string(victim.stdout.take());
        let stderr = read_to_string(victim.stderr.take());
        assert!(
            status.success(),
            "recovery must absorb the worker kill (killed={killed}):\n{stdout}\n{stderr}"
        );
        assert_eq!(solution_fnv_line(&stdout), ref_fnv, "recovered run diverged");
        let a = SolverState::load_path(&ref_ck).expect("reference checkpoint loads");
        let b = SolverState::load_path(&ck).expect("recovered checkpoint loads");
        assert_eq!(a, b, "final checkpoint states diverged");
        // The kill lands right after the pass-1 checkpoint of a 14-pass
        // run, so when it landed on a live worker the trace must carry
        // the recovery event.
        if killed {
            let trace_text = std::fs::read_to_string(&trace).unwrap_or_default();
            assert!(trace_text.contains("recovery"), "missing recovery event:\n{trace_text}");
        }
        let _ = std::fs::remove_dir_all(root);
    }
}
