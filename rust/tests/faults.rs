//! Robustness acceptance tests (ISSUE 8): fault-injected out-of-core
//! solves at the *driver* level. The store's own retry/latch mechanics
//! are unit-tested in `matrix/store/disk.rs`; here the whole solve must
//! honor the contracts:
//!
//! * a transient-fault plan heals through bounded retries and lands
//!   **bitwise identical** to the fault-free solve, with the healed
//!   retries visible in the store stats and the `store_retry` trace;
//! * a permanent fault unwinds into a typed [`SolveError::Store`] whose
//!   message names the last-good checkpoint once the recovery harness
//!   exhausts its attempts;
//! * a single bit flip **anywhere** in a checkpoint file or the tile
//!   store file is refused with a clean error — never a panic, never a
//!   silently accepted wrong value (property-tested over random bits);
//! * a raised interrupt finishes the pass in flight, checkpoints, and
//!   unwinds as [`SolveError::Interrupted`]; resuming that checkpoint
//!   lands bitwise on the uninterrupted run;
//! * the watchdog ends a stalled solve with a structured diagnostic
//!   dump instead of burning the remaining pass budget;
//! * a second solve on a live-locked store is refused with a typed
//!   error instead of corrupting the first solve's file.

use metric_proj::instance::metric_nearness::MetricNearnessInstance;
use metric_proj::matrix::store::{
    snapshot_sibling, DiskStore, FaultPlan, StoreCfg, StoreError,
};
use metric_proj::matrix::PackedSym;
use metric_proj::solver::checkpoint::SolverState;
use metric_proj::solver::nearness::{self, NearnessOpts};
use metric_proj::solver::{recover, OnInterrupt, SolveError, Strategy};
use metric_proj::telemetry::{Event, NullRecorder, Recorder};
use metric_proj::util::interrupt;
use metric_proj::util::proptest::check;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("metric_proj_faults_{tag}_{}", std::process::id()))
}

/// A disk configuration with a fault plan armed and a retry budget deep
/// enough that a transient plan cannot deterministically exhaust it.
fn faulted(dir: &Path, budget: usize, spec: &str, retries: u32) -> StoreCfg {
    let mut cfg = StoreCfg::disk(dir, budget);
    cfg.faults = Some(Arc::new(FaultPlan::parse(spec).expect("valid fault spec")));
    cfg.retries = retries;
    cfg
}

struct VecRecorder(Mutex<Vec<Event>>);

impl Recorder for VecRecorder {
    fn record(&self, ev: &Event) {
        self.0.lock().unwrap().push(ev.clone());
    }
}

#[test]
fn transient_faults_heal_by_retry_and_land_bitwise() {
    // Read EIOs, write-back EIOs, and checksum bit-flips at rates that
    // fault dozens of block operations over the run; every one must heal
    // inside the retry budget and the solve must match the fault-free
    // disk run (and the in-memory run) bit for bit.
    let spec = "seed=9,read-eio=0.03,write-eio=0.02,bitflip=0.01";
    let cases = [
        (26usize, 5usize, 2usize, Strategy::Full),
        (26, 5, 2, Strategy::Active { sweep_every: 3, forget_after: 1 }),
    ];
    for (idx, &(n, tile, threads, strategy)) in cases.iter().enumerate() {
        let inst = MetricNearnessInstance::random(n, 2.0, 77 + idx as u64);
        let opts = NearnessOpts {
            max_passes: 8,
            check_every: 3,
            tol_violation: 1e-12,
            threads,
            tile,
            strategy,
            ..Default::default()
        };
        let ctx = format!("transient case {idx}: {strategy:?}");
        let mem = nearness::solve_stored(&inst, &opts, &StoreCfg::mem(), None, &mut |_| {})
            .expect("mem reference");
        let dir_clean = tmp_dir(&format!("clean{idx}"));
        let clean = nearness::solve_stored(
            &inst,
            &opts,
            &StoreCfg::disk(&dir_clean, 1 << 11),
            None,
            &mut |_| {},
        )
        .expect("fault-free disk reference");
        let dir = tmp_dir(&format!("transient{idx}"));
        let cfg = faulted(&dir, 1 << 11, spec, 8);
        let rec = VecRecorder(Mutex::new(Vec::new()));
        let sol = nearness::solve_traced(&inst, &opts, &cfg, None, &mut |_| {}, &rec)
            .expect("transient faults must heal inside the retry budget");

        assert_eq!(sol.x, clean.x, "{ctx}: x diverged from the fault-free disk run");
        assert_eq!(sol.x, mem.x, "{ctx}: x diverged from the in-memory run");
        assert_eq!(sol.passes, clean.passes, "{ctx}: pass counts diverged");
        assert_eq!(sol.objective, clean.objective, "{ctx}: objective diverged");
        assert_eq!(sol.max_violation, clean.max_violation, "{ctx}: violation diverged");

        let stats = sol.store_stats.expect("disk solve reports store stats");
        assert!(
            stats.retries > 0,
            "{ctx}: the plan {spec} faulted nothing — the test exercised no retry"
        );
        let events = rec.0.lock().unwrap();
        let retried: u64 = events
            .iter()
            .filter_map(|e| match e {
                Event::StoreRetry { retries, detail, .. } => {
                    assert!(
                        detail.contains("attempt"),
                        "{ctx}: retry detail should sample an attempt, got `{detail}`"
                    );
                    Some(*retries)
                }
                _ => None,
            })
            .sum();
        assert!(retried > 0, "{ctx}: no store_retry event reached the trace");

        let clean_stats = clean.store_stats.expect("clean disk stats");
        assert_eq!(clean_stats.retries, 0, "{ctx}: the fault-free run must not retry");

        let _ = std::fs::remove_dir_all(dir);
        let _ = std::fs::remove_dir_all(dir_clean);
    }
}

#[test]
fn permanent_faults_exhaust_recovery_and_name_the_last_good_checkpoint() {
    // Phase 1: a clean disk run leaves a resumable checkpoint. Phase 2:
    // every block read faults (a dead device); the resume fails, the
    // recovery harness reloads the checkpoint and fails again, and the
    // final typed error must name the checkpoint the operator can resume
    // from once the device comes back.
    let n = 22;
    let inst = MetricNearnessInstance::random(n, 2.0, 123);
    let dir = tmp_dir("permanent");
    let ck = tmp_dir("permanent_ck").with_extension("bin");
    let base = NearnessOpts {
        max_passes: 4,
        check_every: 2,
        tol_violation: 1e-12,
        threads: 2,
        tile: 4,
        strategy: Strategy::Full,
        checkpoint_every: 2,
        ..Default::default()
    };
    let clean_cfg = StoreCfg::disk(&dir, 1 << 11);
    nearness::solve_stored(&inst, &base, &clean_cfg, None, &mut |s| {
        s.save_path(&ck).expect("persist checkpoint");
    })
    .expect("clean run");
    let start = SolverState::load_path(&ck).expect("checkpoint loads");
    assert_eq!(start.pass, 4);

    let cfg = faulted(&dir, 1 << 11, "seed=2,read-eio=1.0", 2);
    let resume_opts = NearnessOpts { max_passes: 8, ..base };
    let rec = VecRecorder(Mutex::new(Vec::new()));
    let out = recover::run_with_recovery(1, Some(ck.as_path()), &rec, |recovered| {
        let from = recovered.or(Some(&start));
        nearness::solve_traced(&inst, &resume_opts, &cfg, from, &mut |_| {}, &NullRecorder)
    });
    let err = out.expect_err("a dead device must not produce a solution");
    match &err {
        SolveError::Store { error, last_good_checkpoint } => {
            assert!(
                matches!(error, StoreError::Io(_)),
                "the injected EIO must surface, got {error}"
            );
            assert_eq!(
                last_good_checkpoint.as_deref(),
                Some(ck.as_path()),
                "exhaustion must name the last-good checkpoint"
            );
        }
        other => panic!("wrong unwind: {other}"),
    }
    assert!(
        err.to_string().contains("last good checkpoint"),
        "the operator-facing message must point at the resume path: {err}"
    );
    let recoveries = rec
        .0
        .lock()
        .unwrap()
        .iter()
        .filter(|e| matches!(e, Event::Recovery { .. }))
        .count();
    assert_eq!(recoveries, 1, "exactly one recovery attempt was budgeted");

    let _ = std::fs::remove_dir_all(dir);
    let _ = std::fs::remove_file(ck);
}

#[test]
fn any_single_bit_flip_in_checkpoint_or_store_is_refused_cleanly() {
    // Property: flip one random bit anywhere in the checkpoint file or
    // the tile-store file; loading/resuming must return a clean error.
    // A panic fails the test harness outright, and an `Ok` is a silent
    // acceptance — both are bugs. The store's `.ckpt` snapshot is
    // removed first so snapshot promotion cannot mask the live file's
    // corruption.
    let n = 20;
    let inst = MetricNearnessInstance::random(n, 2.0, 55);
    let dir = tmp_dir("bitflip");
    let ck = tmp_dir("bitflip_ck").with_extension("bin");
    let opts = NearnessOpts {
        max_passes: 4,
        check_every: 2,
        tol_violation: 1e-12,
        threads: 1,
        tile: 4,
        strategy: Strategy::Full,
        checkpoint_every: 2,
        ..Default::default()
    };
    let cfg = StoreCfg::disk(&dir, 1 << 11);
    nearness::solve_stored(&inst, &opts, &cfg, None, &mut |s| {
        s.save_path(&ck).expect("persist checkpoint");
    })
    .expect("clean run");
    let state = SolverState::load_path(&ck).expect("checkpoint loads");
    let pristine_ck = std::fs::read(&ck).expect("checkpoint bytes");
    let pristine_store = std::fs::read(cfg.x_path()).expect("store bytes");
    let _ = std::fs::remove_file(snapshot_sibling(&cfg.x_path()));

    let resume_opts = NearnessOpts { max_passes: 8, ..opts };
    check("single_bit_flip_refusal", 0xB17F11A5, 48, |rng, case| {
        // Alternate targets so both files get even coverage regardless
        // of the case count.
        if case % 2 == 0 {
            let mut bad = pristine_ck.clone();
            let bit = (rng.next_u64() as usize) % (bad.len() * 8);
            bad[bit / 8] ^= 1 << (bit % 8);
            std::fs::write(&ck, &bad).map_err(|e| e.to_string())?;
            match SolverState::load_path(&ck) {
                Err(_) => Ok(()),
                Ok(_) => Err(format!("checkpoint bit {bit} was silently accepted")),
            }
        } else {
            let mut bad = pristine_store.clone();
            let bit = (rng.next_u64() as usize) % (bad.len() * 8);
            bad[bit / 8] ^= 1 << (bit % 8);
            std::fs::write(cfg.x_path(), &bad).map_err(|e| e.to_string())?;
            let _ = std::fs::remove_file(snapshot_sibling(&cfg.x_path()));
            match nearness::solve_stored(&inst, &resume_opts, &cfg, Some(&state), &mut |_| {})
            {
                Err(_) => Ok(()),
                Ok(_) => Err(format!("store bit {bit} was silently accepted")),
            }
        }
    });

    // The pristine pair still resumes — the refusals above were the
    // corruption's fault, not collateral damage from the harness.
    std::fs::write(&ck, &pristine_ck).expect("restore checkpoint");
    std::fs::write(cfg.x_path(), &pristine_store).expect("restore store");
    nearness::solve_stored(&inst, &resume_opts, &cfg, Some(&state), &mut |_| {})
        .expect("pristine files must still resume");

    let _ = std::fs::remove_dir_all(dir);
    let _ = std::fs::remove_file(ck);
}

#[test]
fn a_raised_interrupt_checkpoints_and_unwinds_cleanly() {
    let inst = MetricNearnessInstance::random(20, 2.0, 5);
    let base = NearnessOpts {
        max_passes: 8,
        check_every: 0,
        threads: 2,
        tile: 4,
        strategy: Strategy::Full,
        checkpoint_every: 5,
        on_interrupt: OnInterrupt::Checkpoint,
        ..Default::default()
    };
    interrupt::clear();
    let reference = nearness::solve_stored(&inst, &base, &StoreCfg::mem(), None, &mut |_| {})
        .expect("uninterrupted reference");

    // Raised flag: the solve finishes pass 1, checkpoints (pass 1 is not
    // a periodic boundary, so the interrupt path must emit the state
    // itself), and unwinds with the typed variant.
    let mut states = Vec::new();
    interrupt::raise();
    let err = nearness::solve_traced(
        &inst,
        &base,
        &StoreCfg::mem(),
        None,
        &mut |s| states.push(s.clone()),
        &NullRecorder,
    )
    .expect_err("a raised interrupt must unwind");
    interrupt::clear();
    match err {
        SolveError::Interrupted { pass: 1, checkpointed: true } => {}
        other => panic!("wrong unwind: {other}"),
    }
    assert_eq!(states.len(), 1, "the interrupt path emits exactly one state");
    assert_eq!(states[0].pass, 1);

    // Resuming the interrupt checkpoint lands bitwise on the
    // uninterrupted run — the interrupt lost no work.
    let resumed =
        nearness::solve_stored(&inst, &base, &StoreCfg::mem(), Some(&states[0]), &mut |_| {})
            .expect("resume after interrupt");
    assert_eq!(resumed.x, reference.x, "interrupt/resume diverged");
    assert_eq!(resumed.passes, reference.passes);

    // Without periodic checkpointing there is nothing durable to emit;
    // the unwind must say so instead of pretending.
    let nock = NearnessOpts { checkpoint_every: 0, ..base };
    let mut states = Vec::new();
    interrupt::raise();
    let err = nearness::solve_traced(
        &inst,
        &nock,
        &StoreCfg::mem(),
        None,
        &mut |s| states.push(s.clone()),
        &NullRecorder,
    )
    .expect_err("interrupt with no checkpoint sink");
    interrupt::clear();
    assert!(matches!(err, SolveError::Interrupted { pass: 1, checkpointed: false }));
    assert!(states.is_empty(), "no checkpoint sink configured, none may be emitted");
}

#[test]
fn watchdog_ends_a_stalled_solve_with_a_diagnostic_dump() {
    // Constant distances already satisfy every triangle inequality, so
    // the residual is flat from the first check; a tolerance below the
    // reachable floor keeps the solve running and the watchdog must end
    // it after its stall budget instead of burning all 50 passes.
    let inst = MetricNearnessInstance::new(PackedSym::filled(16, 1.0));
    let opts = NearnessOpts {
        max_passes: 50,
        check_every: 1,
        tol_violation: -2.0,
        threads: 2,
        tile: 4,
        strategy: Strategy::Full,
        watchdog_stall: 3,
        ..Default::default()
    };
    let err =
        nearness::solve_traced(&inst, &opts, &StoreCfg::mem(), None, &mut |_| {}, &NullRecorder)
            .expect_err("a stalled solve must trip the watchdog");
    match err {
        SolveError::Watchdog { pass, report } => {
            assert_eq!(pass, 4, "best at check 1, three flat checks, trip at pass 4");
            assert!(report.contains("\"kind\":\"stall\""), "got {report}");
            assert!(report.contains("watchdog_history"), "dump carries history: {report}");
        }
        other => panic!("wrong unwind: {other}"),
    }
}

#[test]
fn a_second_solve_on_a_live_locked_store_is_refused() {
    // A live store lock (same pid counts — the lockfile holds a running
    // process) must refuse a concurrent solve on the same store with a
    // typed error instead of letting two writers corrupt the file.
    let n = 14;
    let inst = MetricNearnessInstance::random(n, 2.0, 3);
    let dir = tmp_dir("lock");
    let cfg = StoreCfg::disk(&dir, 1 << 10);
    let winv = vec![1.0; n * (n - 1) / 2];
    let holder = DiskStore::create(&cfg.x_path(), n, 4, 1 << 10, winv, &mut |_, _| 1.0)
        .expect("first store acquires the lock");
    // Remove the tile file (the holder keeps its handle) so the second
    // solve takes the create path and hits the lock, not the
    // overwrite-refusal guard.
    std::fs::remove_file(cfg.x_path()).expect("unlink tile file");
    let opts = NearnessOpts {
        max_passes: 2,
        check_every: 0,
        threads: 1,
        tile: 4,
        strategy: Strategy::Full,
        ..Default::default()
    };
    let err = nearness::solve_stored(&inst, &opts, &cfg, None, &mut |_| {})
        .expect_err("a live lock must refuse the second solve");
    assert!(
        format!("{err:?}").contains("locked"),
        "error should name the lock: {err:?}"
    );
    drop(holder);
    let _ = std::fs::remove_dir_all(dir);
}
