//! Warm-start acceptance: perturbed re-solves must reach tolerance in
//! strictly fewer passes than a cold start, landing on the same optimum.
//! Also pins the trusted-sweep termination contract the warm-start
//! benchmark relies on: reported residuals come from the exact
//! confirming scan, never the sweep's stale screen.

use metric_proj::eval;
use metric_proj::instance::metric_nearness::{max_triangle_violation, MetricNearnessInstance};
use metric_proj::instance::CcLpInstance;
use metric_proj::solver::checkpoint::{self, SolverState, WarmStartOpts};
use metric_proj::solver::nearness::{self, NearnessOpts};
use metric_proj::solver::{dykstra_parallel, SolveOpts, Strategy};

fn ablation_opts(strategy: Strategy, tol: f64) -> SolveOpts {
    SolveOpts {
        max_passes: 20_000,
        check_every: 2,
        tol_violation: tol,
        tol_gap: 1e30, // violation-driven stop for a clean pass comparison
        threads: 2,
        tile: 10,
        strategy,
        ..Default::default()
    }
}

/// Core warm-start claim at a CI-friendly size, for both strategies:
/// strictly fewer passes to tolerance, same optimum.
#[test]
fn warm_start_beats_cold_on_perturbed_cclp() {
    let base = CcLpInstance::random(60, 0.5, 0.8, 1.6, 5);
    let perturbed = base.perturb_weights(0.1, 0.2, 6);
    for strategy in
        [Strategy::Full, Strategy::Active { sweep_every: 4, forget_after: 2 }]
    {
        let opts = ablation_opts(strategy, 1e-7);
        let ab = eval::warm_start_ablation(&base, &perturbed, &opts, &WarmStartOpts::default())
            .unwrap();
        assert!(ab.cold.passes < 20_000, "{strategy:?}: cold failed to converge");
        assert!(ab.warm.passes < 20_000, "{strategy:?}: warm failed to converge");
        assert!(
            ab.warm.passes < ab.cold.passes,
            "{strategy:?}: warm {} !< cold {}",
            ab.warm.passes,
            ab.cold.passes
        );
        assert!(ab.warm.max_violation <= 1e-7, "{strategy:?}");
        let rel = (ab.warm.lp_objective - ab.cold.lp_objective).abs()
            / ab.cold.lp_objective.abs().max(1.0);
        assert!(rel <= 1e-4, "{strategy:?}: objectives differ by {rel:.2e}");
    }
}

/// ISSUE acceptance (slow: n = 120, run by the nightly `--ignored` CI
/// job): perturb 10% of the weights of an n = 120 CC-LP instance; warm
/// start must reach tolerance in strictly fewer passes than cold start
/// with the final objective within 1e-6.
#[test]
#[ignore = "n = 120 acceptance run; exercised by the slow-tests CI job"]
fn warm_start_acceptance_n120() {
    let base = CcLpInstance::random(120, 0.5, 0.8, 1.6, 42);
    let perturbed = base.perturb_weights(0.1, 0.2, 43);
    let strategy = Strategy::Active { sweep_every: 5, forget_after: 2 };
    // Tighten the tolerance until the two optima agree to 1e-6: both
    // converge to the same unique projection, so the ladder terminates.
    let mut tol = 1e-7f64;
    loop {
        let opts = ablation_opts(strategy, tol);
        let ab = eval::warm_start_ablation(&base, &perturbed, &opts, &WarmStartOpts::default())
            .unwrap();
        assert!(ab.cold.passes < 20_000, "cold failed to converge at tol {tol:.0e}");
        assert!(ab.warm.passes < 20_000, "warm failed to converge at tol {tol:.0e}");
        assert!(
            ab.warm.passes < ab.cold.passes,
            "tol {tol:.0e}: warm {} !< cold {}",
            ab.warm.passes,
            ab.cold.passes
        );
        assert!(
            ab.warm.metric_visits < ab.cold.metric_visits,
            "tol {tol:.0e}: warm must also do less metric work"
        );
        let rel = (ab.warm.lp_objective - ab.cold.lp_objective).abs()
            / ab.cold.lp_objective.abs().max(1.0);
        if rel <= 1e-6 {
            break;
        }
        tol /= 10.0;
        assert!(tol >= 1e-12, "ladder exhausted: objectives still differ by {rel:.2e}");
    }
}

/// Warm starts help metric nearness re-solves too (weights perturbed,
/// dissimilarities unchanged).
#[test]
fn warm_start_beats_cold_on_perturbed_nearness() {
    let base = MetricNearnessInstance::random(40, 2.0, 9);
    let perturbed = base.perturb_weights(0.15, 0.25, 10);
    let opts = NearnessOpts {
        max_passes: 20_000,
        check_every: 2,
        tol_violation: 1e-8,
        threads: 2,
        tile: 8,
        strategy: Strategy::Active { sweep_every: 4, forget_after: 2 },
        checkpoint_every: usize::MAX,
        ..Default::default()
    };
    let mut last: Option<SolverState> = None;
    let base_sol =
        nearness::solve_checkpointed(&base, &opts, None, &mut |s| last = Some(s.clone()))
            .unwrap();
    assert!(base_sol.passes < 20_000, "base failed to converge");
    let ckpt = last.unwrap();
    let run_opts = NearnessOpts { checkpoint_every: 0, ..opts };
    let cold = nearness::solve(&perturbed, &run_opts);
    let seed =
        checkpoint::warm_start_nearness(&ckpt, &perturbed, &WarmStartOpts::default()).unwrap();
    let warm = nearness::resume(&perturbed, &run_opts, &seed).unwrap();
    assert!(cold.passes < 20_000 && warm.passes < 20_000);
    assert!(
        warm.passes < cold.passes,
        "warm {} !< cold {}",
        warm.passes,
        cold.passes
    );
    assert!(warm.max_violation <= 1e-8);
    assert!(max_triangle_violation(&warm.x) <= 1e-8);
    let rel = (warm.objective - cold.objective).abs() / cold.objective.max(1.0);
    assert!(rel <= 1e-4, "objectives differ by {rel:.2e}");
}

/// Regression (ISSUE satellite): when the active strategy stops via the
/// trusted-sweep screen, the recorded `Residuals::max_violation` must be
/// the exact confirming scan's value — recomputable from the returned
/// iterate — not the sweep's mid-pass measurement, which is one pair
/// phase stale.
#[test]
fn early_stop_records_the_exact_confirming_scan() {
    let inst = CcLpInstance::random(24, 0.5, 0.8, 1.6, 77);
    let opts = SolveOpts {
        max_passes: 20_000,
        check_every: 1,
        tol_violation: 1e-6,
        tol_gap: 1e30,
        threads: 2,
        tile: 5,
        strategy: Strategy::Active { sweep_every: 5, forget_after: 2 },
        checkpoint_every: usize::MAX,
        ..Default::default()
    };
    let mut last: Option<SolverState> = None;
    let sol = dykstra_parallel::solve_checkpointed(&inst, &opts, None, &mut |s| {
        last = Some(s.clone())
    })
    .unwrap();
    assert!(sol.passes < 20_000, "expected an early stop");
    // Recompute the exact violation from the returned iterate alone: the
    // metric part from x, the pair/box part from (x, f, d).
    let f = sol.f.as_ref().expect("CC solutions carry slacks");
    let metric = max_triangle_violation(&sol.x);
    let mut pair = f64::NEG_INFINITY;
    for (i, j, xv) in sol.x.iter_pairs() {
        let dev = (xv - inst.d.get(i, j)).abs() - f.get(i, j);
        pair = pair.max(dev).max(xv - 1.0);
    }
    let expect = metric.max(pair).max(0.0);
    assert_eq!(
        sol.residuals.max_violation, expect,
        "reported violation must be the exact confirming scan's value"
    );
    assert!(sol.residuals.max_violation <= 1e-6);
    // The termination history's final record is that same exact value —
    // not the sweep screen that triggered the confirmation.
    let st = last.expect("final checkpoint emitted");
    let final_check = st.history.last().expect("early stop implies a check record");
    assert_eq!(final_check.pass, sol.passes as u64);
    assert_eq!(final_check.max_violation, sol.residuals.max_violation);

    // Same contract on the nearness driver.
    let ninst = MetricNearnessInstance::random(20, 2.0, 78);
    let nopts = NearnessOpts {
        max_passes: 20_000,
        check_every: 1,
        tol_violation: 1e-7,
        threads: 2,
        tile: 4,
        strategy: Strategy::Active { sweep_every: 5, forget_after: 2 },
        checkpoint_every: usize::MAX,
        ..Default::default()
    };
    let mut nlast: Option<SolverState> = None;
    let nsol = nearness::solve_checkpointed(&ninst, &nopts, None, &mut |s| {
        nlast = Some(s.clone())
    })
    .unwrap();
    assert!(nsol.passes < 20_000, "expected an early stop");
    assert_eq!(
        nsol.max_violation,
        max_triangle_violation(&nsol.x).max(0.0),
        "nearness must report the exact scan of the returned x"
    );
    let nst = nlast.unwrap();
    let nfinal = nst.history.last().unwrap();
    assert_eq!(nfinal.max_violation, nsol.max_violation);
}
