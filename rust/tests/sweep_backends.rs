//! ISSUE 3 acceptance: the screen-then-project sweep backends are
//! bitwise interchangeable. `Screened` (and `Engine`, which falls back
//! to `Screened` under the offline PJRT stub) must reproduce the
//! `Scalar` callback sweep exactly — same `x` trajectory, same rebuilt
//! active set, same measured violations, same work counters — across
//! thread counts, tile sizes, and both the CC-LP and nearness drivers.

use metric_proj::instance::metric_nearness::MetricNearnessInstance;
use metric_proj::instance::CcLpInstance;
use metric_proj::prop_assert;
use metric_proj::solver::nearness::{self, NearnessOpts};
use metric_proj::solver::{
    dykstra_parallel, SolveOpts, Strategy, SweepBackend, SweepPolicy,
};
use metric_proj::util::proptest::check;

const BACKENDS: [SweepBackend; 3] =
    [SweepBackend::Scalar, SweepBackend::Screened, SweepBackend::Engine];

fn active(sweep_every: usize, forget_after: usize) -> Strategy {
    Strategy::Active { sweep_every, forget_after }
}

/// CC-LP driver: every backend produces the identical Solution, for the
/// ISSUE's grid of thread counts and tile sizes. check_every exercises
/// the trusted-sweep termination path (identical iterates => identical
/// stopping decisions).
#[test]
fn cc_backends_bitwise_identical() {
    for &tile in &[2usize, 4, 7] {
        for &p in &[1usize, 3] {
            let inst = CcLpInstance::random(16, 0.5, 0.8, 1.6, 7 + tile as u64);
            let base = SolveOpts {
                max_passes: 14,
                check_every: 3,
                threads: p,
                tile,
                strategy: active(3, 2),
                ..Default::default()
            };
            let sols: Vec<_> = BACKENDS
                .iter()
                .map(|&b| dykstra_parallel::solve(&inst, &SolveOpts { sweep_backend: b, ..base }))
                .collect();
            let scalar = &sols[0];
            assert_eq!(scalar.sweep_projected, scalar.sweep_screened, "scalar projects all");
            for (sol, backend) in sols.iter().zip(BACKENDS).skip(1) {
                let ctx = format!("{backend:?} p={p} tile={tile}");
                assert_eq!(scalar.x, sol.x, "x diverged ({ctx})");
                assert_eq!(scalar.f, sol.f, "slacks diverged ({ctx})");
                assert_eq!(scalar.passes, sol.passes, "{ctx}");
                assert_eq!(scalar.nnz_duals, sol.nnz_duals, "{ctx}");
                assert_eq!(scalar.metric_visits, sol.metric_visits, "{ctx}");
                assert_eq!(scalar.active_triplets, sol.active_triplets, "{ctx}");
                assert_eq!(
                    scalar.residuals.max_violation, sol.residuals.max_violation,
                    "{ctx}"
                );
                assert_eq!(scalar.sweep_screened, sol.sweep_screened, "{ctx}");
                assert!(sol.sweep_projected <= sol.sweep_screened, "{ctx}");
                // The screen only skips provable no-ops, so both screened
                // backends agree on what needed projecting.
                assert_eq!(sols[1].sweep_projected, sol.sweep_projected, "{ctx}");
            }
        }
    }
}

/// Nearness driver: same grid, same bitwise pin.
#[test]
fn nearness_backends_bitwise_identical() {
    for &tile in &[2usize, 4, 7] {
        for &p in &[1usize, 3] {
            let inst = MetricNearnessInstance::random(18, 2.0, 19 + tile as u64);
            let base = NearnessOpts {
                max_passes: 14,
                check_every: 3,
                threads: p,
                tile,
                strategy: active(4, 1),
                ..Default::default()
            };
            let sols: Vec<_> = BACKENDS
                .iter()
                .map(|&b| nearness::solve(&inst, &NearnessOpts { sweep_backend: b, ..base }))
                .collect();
            let scalar = &sols[0];
            for (sol, backend) in sols.iter().zip(BACKENDS).skip(1) {
                let ctx = format!("{backend:?} p={p} tile={tile}");
                assert_eq!(scalar.x, sol.x, "x diverged ({ctx})");
                assert_eq!(scalar.passes, sol.passes, "{ctx}");
                assert_eq!(scalar.max_violation, sol.max_violation, "{ctx}");
                assert_eq!(scalar.metric_visits, sol.metric_visits, "{ctx}");
                assert_eq!(scalar.active_triplets, sol.active_triplets, "{ctx}");
                assert_eq!(scalar.sweep_screened, sol.sweep_screened, "{ctx}");
                assert!(sol.sweep_projected <= sol.sweep_screened, "{ctx}");
            }
        }
    }
}

/// Property form: random instances, strategies, and shapes — the
/// screened backend never diverges from scalar by a single bit.
#[test]
fn backend_equivalence_property() {
    check("screened sweep == scalar sweep", 0x5C2EE7, 10, |rng, _| {
        let n = rng.usize_in(6, 22);
        let tile = rng.usize_in(1, 8);
        let p = rng.usize_in(1, 4);
        let strategy = active(rng.usize_in(1, 6), rng.usize_in(0, 4));
        let cc = rng.bool(0.5);
        if cc {
            let inst = CcLpInstance::random(n, 0.5, 0.8, 1.6, rng.next_u64());
            let base = SolveOpts {
                max_passes: 10,
                threads: p,
                tile,
                strategy,
                ..Default::default()
            };
            let a = dykstra_parallel::solve(
                &inst,
                &SolveOpts { sweep_backend: SweepBackend::Scalar, ..base },
            );
            let b = dykstra_parallel::solve(
                &inst,
                &SolveOpts { sweep_backend: SweepBackend::Screened, ..base },
            );
            prop_assert!(a.x == b.x, "CC x diverged (n={n} tile={tile} p={p})");
            prop_assert!(a.nnz_duals == b.nnz_duals, "CC duals diverged (n={n})");
        } else {
            let inst = MetricNearnessInstance::random(n.max(8), 2.0, rng.next_u64());
            let base = NearnessOpts {
                max_passes: 10,
                threads: p,
                tile,
                strategy,
                ..Default::default()
            };
            let a = nearness::solve(
                &inst,
                &NearnessOpts { sweep_backend: SweepBackend::Scalar, ..base },
            );
            let b = nearness::solve(
                &inst,
                &NearnessOpts { sweep_backend: SweepBackend::Screened, ..base },
            );
            prop_assert!(a.x == b.x, "nearness x diverged (n={n} tile={tile} p={p})");
        }
        Ok(())
    });
}

/// The adaptive cadence is a drop-in replacement: it converges to the
/// same tolerance and runs fewer sweeps than an every-other-pass fixed
/// cadence on a well-behaved instance.
#[test]
fn adaptive_cadence_converges_with_fewer_sweeps() {
    let inst = MetricNearnessInstance::random(24, 2.0, 33);
    let base = NearnessOpts {
        max_passes: 4000,
        check_every: 2,
        tol_violation: 1e-7,
        threads: 2,
        tile: 6,
        strategy: active(2, 2),
        ..Default::default()
    };
    let fixed = nearness::solve(&inst, &base);
    let adaptive = nearness::solve(
        &inst,
        &NearnessOpts { sweep_policy: Some(SweepPolicy::Adaptive), ..base },
    );
    assert!(fixed.passes < 4000, "fixed cadence failed to converge");
    assert!(adaptive.passes < 4000, "adaptive cadence failed to converge");
    assert!(adaptive.max_violation <= 1e-7, "violation {}", adaptive.max_violation);
    let sweeps = |screened: u64| screened / metric_proj::solver::schedule::n_triplets(24);
    assert!(
        sweeps(adaptive.sweep_screened) < sweeps(fixed.sweep_screened),
        "adaptive ran {} sweeps vs fixed {}",
        sweeps(adaptive.sweep_screened),
        sweeps(fixed.sweep_screened)
    );
}

/// Adaptive stays bitwise thread-count invariant: its signals (set
/// sizes, sweep violations) are themselves p-invariant.
#[test]
fn adaptive_cadence_is_thread_count_invariant() {
    let inst = CcLpInstance::random(14, 0.5, 0.8, 1.6, 91);
    let mk = |p: usize| SolveOpts {
        max_passes: 25,
        threads: p,
        tile: 3,
        strategy: active(4, 1),
        sweep_policy: Some(SweepPolicy::Adaptive),
        ..Default::default()
    };
    let a = dykstra_parallel::solve(&inst, &mk(1));
    let b = dykstra_parallel::solve(&inst, &mk(4));
    assert_eq!(a.x, b.x);
    assert_eq!(a.metric_visits, b.metric_visits);
    assert_eq!(a.sweep_screened, b.sweep_screened);
    assert_eq!(a.sweep_projected, b.sweep_projected);
}
