//! Out-of-core acceptance tests: a solve backed by the disk tile store —
//! under a cache budget small enough to force eviction churn — must be
//! **bitwise identical** to the in-memory solve, for any tile size,
//! thread count, and strategy. Covers the nearness drivers (metric
//! phases only) and, since PR 5, the CC-LP drivers too (metric + pair
//! phases + residual scans, with the weighted instance's `W` streamed
//! from the store's second plane). Disk-backed checkpoints reference the
//! store file (no inline `x`) and resume bitwise; a corrupted,
//! truncated, or drifted store file is refused, mirroring
//! `tests/checkpoint_roundtrip.rs`. Since PR 7 the active cheap passes
//! lease entry-granular subsets of each tile (`with_entries`); the same
//! bitwise contract holds, and the `entry_loads` / `blocks_skipped`
//! counters must prove the sparse gathers skip footprint blocks.
//!
//! Thread counts marked with [`env_threads`] honor the CI matrix's
//! `METRIC_PROJ_TEST_THREADS` override — results are bitwise
//! thread-count independent, so any override keeps the assertions valid.

use metric_proj::instance::metric_nearness::MetricNearnessInstance;
use metric_proj::instance::CcLpInstance;
use metric_proj::matrix::store::{DiskStore, StoreCfg, TileScratch, TileStore};
use metric_proj::solver::checkpoint::SolverState;
use metric_proj::solver::nearness::{self, NearnessOpts, NearnessSolution};
use metric_proj::solver::schedule::Schedule;
use metric_proj::solver::{dykstra_parallel, Solution, SolveOpts, Strategy};
use metric_proj::util::parallel::env_threads;
use std::path::PathBuf;

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("metric_proj_store_eq_{tag}_{}", std::process::id()))
}

fn solve_collecting(
    inst: &MetricNearnessInstance,
    opts: &NearnessOpts,
    cfg: &StoreCfg,
    resume: Option<&SolverState>,
) -> (NearnessSolution, Vec<SolverState>) {
    let mut states = Vec::new();
    let sol = nearness::solve_stored(inst, opts, cfg, resume, &mut |s| states.push(s.clone()))
        .expect("solve_stored");
    (sol, states)
}

fn assert_same_solution(a: &NearnessSolution, b: &NearnessSolution, ctx: &str) {
    assert_eq!(a.x, b.x, "{ctx}: x diverged");
    assert_eq!(a.passes, b.passes, "{ctx}: pass counts diverged");
    assert_eq!(a.metric_visits, b.metric_visits, "{ctx}: work accounting diverged");
    assert_eq!(a.max_violation, b.max_violation, "{ctx}: reported violation diverged");
    assert_eq!(a.objective, b.objective, "{ctx}: objective diverged");
}

fn cc_solve_collecting(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    cfg: &StoreCfg,
    resume: Option<&SolverState>,
) -> (Solution, Vec<SolverState>) {
    let mut states = Vec::new();
    let sol =
        dykstra_parallel::solve_stored(inst, opts, cfg, resume, &mut |s| states.push(s.clone()))
            .expect("solve_stored");
    (sol, states)
}

fn assert_same_cc_solution(a: &Solution, b: &Solution, ctx: &str) {
    assert_eq!(a.x, b.x, "{ctx}: x diverged");
    assert_eq!(a.f, b.f, "{ctx}: slacks diverged");
    assert_eq!(a.passes, b.passes, "{ctx}: pass counts diverged");
    assert_eq!(a.nnz_duals, b.nnz_duals, "{ctx}: dual counts diverged");
    assert_eq!(a.metric_visits, b.metric_visits, "{ctx}: work accounting diverged");
    assert_eq!(
        a.residuals.max_violation, b.residuals.max_violation,
        "{ctx}: reported violation diverged"
    );
    assert_eq!(a.residuals.qp_primal, b.residuals.qp_primal, "{ctx}: primal diverged");
    assert_eq!(a.residuals.qp_dual, b.residuals.qp_dual, "{ctx}: dual objective diverged");
    assert_eq!(a.residuals.rel_gap, b.residuals.rel_gap, "{ctx}: gap diverged");
    assert_eq!(
        a.residuals.lp_objective, b.residuals.lp_objective,
        "{ctx}: LP objective diverged"
    );
}

#[test]
fn cc_disk_and_mem_solves_are_bitwise_identical_under_churn() {
    // The CC-LP drivers stream the metric phases, the pair phase, and
    // the residual scans through the store; weighted instances
    // additionally stream W from the second plane. Bitwise equality must
    // survive eviction churn for the full and active strategies alike.
    let cases = [
        // (n, tile, threads, strategy, budget_bytes, check_every)
        (24usize, 4usize, 1usize, Strategy::Full, 1usize << 11, 5usize),
        (24, 4, env_threads(3), Strategy::Full, 1 << 11, 5),
        (26, 5, env_threads(2), Strategy::Active { sweep_every: 3, forget_after: 1 }, 1 << 11, 4),
        // tile > n: the whole matrix is one block — no eviction possible,
        // but the single-block path must still be bitwise clean.
        (20, 40, 2, Strategy::Active { sweep_every: 2, forget_after: 0 }, 1 << 10, 3),
        // m = 1225 >= 1024: the residual reductions leave their serial
        // fallback and take the chunked parallel branch — the code that
        // carries the bitwise summation-order contract must run in PR
        // CI, not only in the nightly n=120 acceptance.
        (50, 8, env_threads(3), Strategy::Active { sweep_every: 3, forget_after: 1 }, 1 << 12, 4),
    ];
    for (idx, &(n, tile, threads, strategy, budget, check_every)) in cases.iter().enumerate() {
        // Weighted instance: w in [0.8, 1.6], so the streamed W plane
        // carries non-trivial values.
        let inst = CcLpInstance::random(n, 0.5, 0.8, 1.6, 31 + idx as u64);
        let opts = SolveOpts {
            max_passes: 10,
            check_every,
            tol_violation: 1e-12,
            tol_gap: 1e-12,
            threads,
            tile,
            strategy,
            ..Default::default()
        };
        let ctx = format!("cc case {idx}: n={n} tile={tile} p={threads} {strategy:?}");
        let (mem, _) = cc_solve_collecting(&inst, &opts, &StoreCfg::mem(), None);
        assert!(mem.store_stats.is_none(), "{ctx}: mem solves carry no store stats");
        let dir = tmp_dir(&format!("cc{idx}"));
        let (disk, _) = cc_solve_collecting(&inst, &opts, &StoreCfg::disk(&dir, budget), None);
        assert_same_cc_solution(&mem, &disk, &ctx);
        let stats = disk.store_stats.expect("disk solve reports store stats");
        assert!(stats.loads > 0, "{ctx}: no blocks were ever loaded");
        assert!(stats.w_loads > 0, "{ctx}: the W plane must stream");
        let evictable = n.div_ceil(tile) > 1 && budget < n * (n - 1) / 2 * 8;
        if evictable {
            assert!(
                stats.evictions > 0,
                "{ctx}: budget {budget} was too generous to exercise eviction"
            );
            assert!(stats.writebacks > 0, "{ctx}: dirty blocks must be written back");
        }
        if matches!(strategy, Strategy::Active { .. }) {
            assert!(
                stats.entry_loads > 0,
                "{ctx}: cheap passes must gather through entry leases"
            );
            if tile < n {
                assert!(
                    stats.blocks_skipped > 0,
                    "{ctx}: sparse buckets must skip part of the tile footprint"
                );
            }
        }
        let _ = std::fs::remove_dir_all(dir);
    }
}

#[test]
fn active_cheap_passes_stream_entry_leases_and_skip_footprint_blocks() {
    // PR 7: the cheap passes of an active disk solve lease only the
    // entries named by each tile bucket instead of the whole pair
    // footprint. The solve must stay bitwise identical to the in-memory
    // run, and the store counters must show both the entry gathers and
    // the footprint blocks they skipped. Geometry keeps block == tile
    // well below n so every tile footprint spans several cache blocks.
    let cases = [
        // (n, tile, threads, budget_bytes)
        (40usize, 5usize, 1usize, 1usize << 12),
        (40, 5, env_threads(3), 1 << 12),
        (34, 8, env_threads(2), 1 << 11),
    ];
    for (idx, &(n, tile, threads, budget)) in cases.iter().enumerate() {
        let inst = MetricNearnessInstance::random(n, 2.0, 61 + idx as u64);
        let opts = NearnessOpts {
            max_passes: 12,
            check_every: 4,
            tol_violation: 1e-12,
            threads,
            tile,
            strategy: Strategy::Active { sweep_every: 3, forget_after: 2 },
            ..Default::default()
        };
        let ctx = format!("entry-lease case {idx}: n={n} tile={tile} p={threads}");
        let (mem, _) = solve_collecting(&inst, &opts, &StoreCfg::mem(), None);
        let dir = tmp_dir(&format!("entry{idx}"));
        let (disk, _) = solve_collecting(&inst, &opts, &StoreCfg::disk(&dir, budget), None);
        assert_same_solution(&mem, &disk, &ctx);
        let stats = disk.store_stats.expect("disk solve reports store stats");
        assert!(
            stats.entry_loads > 0,
            "{ctx}: cheap passes must gather through entry leases"
        );
        assert!(
            stats.blocks_skipped > 0,
            "{ctx}: sparse buckets must skip part of the tile footprint \
             ({} entries gathered, {} block loads)",
            stats.entry_loads,
            stats.loads
        );
        let _ = std::fs::remove_dir_all(dir);
    }
}

#[test]
fn cc_disk_checkpoints_reference_the_store_and_resume_bitwise() {
    let n = 28;
    let inst = CcLpInstance::random(n, 0.5, 0.8, 1.6, 13);
    let strategy = Strategy::Active { sweep_every: 3, forget_after: 1 };
    let base = SolveOpts {
        check_every: 2,
        tol_violation: 1e-12,
        tol_gap: 1e-12,
        threads: env_threads(2),
        tile: 5,
        strategy,
        checkpoint_every: 2,
        ..Default::default()
    };
    let budget = 1 << 12;

    // Uninterrupted references, memory and disk.
    let full_opts = SolveOpts { max_passes: 9, ..base };
    let (mem_ref, _) = cc_solve_collecting(&inst, &full_opts, &StoreCfg::mem(), None);
    let dir_ref = tmp_dir("cc_ckpt_ref");
    let (disk_ref, _) =
        cc_solve_collecting(&inst, &full_opts, &StoreCfg::disk(&dir_ref, budget), None);
    assert_same_cc_solution(&mem_ref, &disk_ref, "uninterrupted CC disk run");

    // Interrupt at pass 4: the emitted states must reference the store
    // instead of re-serializing x (slacks and pair duals stay inline).
    let dir = tmp_dir("cc_ckpt_resume");
    let cfg = StoreCfg::disk(&dir, budget);
    let half_opts = SolveOpts { max_passes: 4, ..base };
    let (_half, states) = cc_solve_collecting(&inst, &half_opts, &cfg, None);
    let last = states.last().expect("checkpoints were emitted");
    assert_eq!(last.pass, 4);
    assert!(last.x_external, "CC disk checkpoints must reference the store");
    assert!(last.x.is_empty(), "external checkpoints must not inline x");
    let m = n * (n - 1) / 2;
    assert_eq!(last.f.len(), m, "slacks stay inline");
    assert_eq!(last.y_upper.len(), m, "pair duals stay inline");
    // The state survives its byte format (save -> load).
    let mut bytes = Vec::new();
    last.save(&mut bytes).expect("save");
    let reloaded = SolverState::load(&mut bytes.as_slice()).expect("load");
    assert_eq!(*last, reloaded);

    // Resume against the same store: lands bitwise on the references.
    let (resumed, _) = cc_solve_collecting(&inst, &full_opts, &cfg, Some(&reloaded));
    assert_same_cc_solution(&mem_ref, &resumed, "CC interrupt/resume vs uninterrupted");

    let _ = std::fs::remove_dir_all(dir);
    let _ = std::fs::remove_dir_all(dir_ref);
}

#[test]
fn cc_inline_checkpoint_seeds_a_disk_resume_and_mem_refuses_external() {
    let n = 22;
    let inst = CcLpInstance::random(n, 0.5, 0.8, 1.6, 41);
    let base = SolveOpts {
        check_every: 0,
        threads: 2,
        tile: 4,
        strategy: Strategy::Full,
        checkpoint_every: 3,
        ..Default::default()
    };
    // Inline (mem) checkpoint -> disk resume matches the uninterrupted
    // in-memory run bitwise.
    let (mem_ref, _) = cc_solve_collecting(
        &inst,
        &SolveOpts { max_passes: 8, ..base },
        &StoreCfg::mem(),
        None,
    );
    let (_, states) = cc_solve_collecting(
        &inst,
        &SolveOpts { max_passes: 3, ..base },
        &StoreCfg::mem(),
        None,
    );
    let st = states.last().expect("checkpoint emitted");
    assert!(!st.x_external);
    let dir = tmp_dir("cc_inline_to_disk");
    let (resumed, disk_states) = cc_solve_collecting(
        &inst,
        &SolveOpts { max_passes: 8, ..base },
        &StoreCfg::disk(&dir, 1 << 11),
        Some(st),
    );
    assert_same_cc_solution(&mem_ref, &resumed, "CC inline checkpoint -> disk resume");
    // ...and the disk run's own checkpoints are external; feeding one to
    // the memory backend must be refused.
    let ext = disk_states.last().expect("disk checkpoints emitted");
    assert!(ext.x_external);
    let err = dykstra_parallel::solve_stored(
        &inst,
        &SolveOpts { max_passes: 9, ..base },
        &StoreCfg::mem(),
        Some(ext),
        &mut |_| {},
    )
    .expect_err("memory backend must refuse an external-x CC checkpoint");
    assert!(
        format!("{err:?}").contains("external"),
        "error should explain the external reference: {err:?}"
    );
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
#[ignore = "nightly acceptance: n >= 120 CC-LP disk solve under a forced-eviction budget"]
fn acceptance_n120_cc_disk_solve_under_budget_matches_mem_bitwise() {
    // ISSUE acceptance: a weighted n >= 120 CC-LP instance solved with a
    // store budget far below the packed X (7140 entries = 55.8 KiB),
    // forcing eviction churn in both planes, lands bitwise on the
    // in-memory solution — full pipeline: sweeps, cheap passes, pair
    // phase, residual checks, and the final extraction.
    let n = 120;
    let inst = CcLpInstance::random(n, 0.5, 0.8, 1.6, 120);
    let opts = SolveOpts {
        max_passes: 6,
        check_every: 3,
        tol_violation: 1e-12,
        tol_gap: 1e-12,
        threads: env_threads(2),
        tile: 30,
        strategy: Strategy::Active { sweep_every: 3, forget_after: 2 },
        ..Default::default()
    };
    let (mem, _) = cc_solve_collecting(&inst, &opts, &StoreCfg::mem(), None);
    let dir = tmp_dir("cc_n120");
    let budget = 16 << 10;
    assert!(budget < n * (n - 1) / 2 * 8, "budget must undercut the packed X");
    let (disk, _) = cc_solve_collecting(&inst, &opts, &StoreCfg::disk(&dir, budget), None);
    assert_same_cc_solution(&mem, &disk, "n=120 CC acceptance");
    let stats = disk.store_stats.expect("disk solve reports store stats");
    assert!(stats.evictions > 0, "n=120 run must churn the cache (budget {budget})");
    assert!(stats.w_loads > 0, "weighted W must stream");
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn disk_and_mem_solves_are_bitwise_identical_under_churn() {
    // Tiny cache budgets force continuous load/evict/write-back while
    // the solve runs; the result must not change by a single bit.
    let cases = [
        // (n, tile, threads, strategy, budget_bytes, check_every)
        (24usize, 4usize, 1usize, Strategy::Full, 1 << 11, 5usize),
        (24, 4, 3, Strategy::Full, 1 << 11, 5),
        (30, 7, 2, Strategy::Active { sweep_every: 3, forget_after: 1 }, 1 << 11, 4),
        (37, 5, 3, Strategy::Active { sweep_every: 4, forget_after: 2 }, 1 << 12, 0),
        // tile > n: the whole matrix is one block — no eviction possible,
        // but the single-block path must still be bitwise clean.
        (19, 40, 2, Strategy::Active { sweep_every: 2, forget_after: 0 }, 1 << 10, 3),
    ];
    for (idx, &(n, tile, threads, strategy, budget, check_every)) in cases.iter().enumerate() {
        let inst = MetricNearnessInstance::random(n, 2.0, 7 + idx as u64);
        let opts = NearnessOpts {
            max_passes: 12,
            check_every,
            tol_violation: 1e-9,
            threads,
            tile,
            strategy,
            ..Default::default()
        };
        let ctx = format!("case {idx}: n={n} tile={tile} p={threads} {strategy:?}");
        let (mem, _) = solve_collecting(&inst, &opts, &StoreCfg::mem(), None);
        let dir = tmp_dir(&format!("prop{idx}"));
        let (disk, _) = solve_collecting(&inst, &opts, &StoreCfg::disk(&dir, budget), None);
        assert_same_solution(&mem, &disk, &ctx);
        let stats = disk.store_stats.expect("disk solve reports store stats");
        assert!(stats.loads > 0, "{ctx}: no blocks were ever loaded");
        // Eviction is only possible with more than one block and a
        // budget below the packed total.
        let evictable = n.div_ceil(tile) > 1 && budget < n * (n - 1) / 2 * 8;
        if evictable {
            assert!(
                stats.evictions > 0,
                "{ctx}: budget {budget} was too generous to exercise eviction"
            );
            assert!(stats.writebacks > 0, "{ctx}: dirty blocks must be written back");
        }
        let _ = std::fs::remove_dir_all(dir);
    }
}

#[test]
fn acceptance_n200_disk_solve_under_budget_matches_mem_bitwise() {
    // ISSUE acceptance: an n >= 200 instance solved with a store budget
    // smaller than the full packed X (19900 entries = 155.5 KiB here,
    // budget 32 KiB), forcing tile eviction, lands bitwise on the
    // in-memory solution.
    let n = 200;
    let inst = MetricNearnessInstance::random(n, 2.0, 42);
    let opts = NearnessOpts {
        max_passes: 7,
        check_every: 3,
        tol_violation: 1e-12,
        threads: 2,
        tile: 40,
        strategy: Strategy::Active { sweep_every: 3, forget_after: 2 },
        ..Default::default()
    };
    let (mem, _) = solve_collecting(&inst, &opts, &StoreCfg::mem(), None);
    let dir = tmp_dir("n200");
    let budget = 32 << 10;
    assert!(budget < n * (n - 1) / 2 * 8, "budget must undercut the packed X");
    let (disk, _) = solve_collecting(&inst, &opts, &StoreCfg::disk(&dir, budget), None);
    assert_same_solution(&mem, &disk, "n=200 acceptance");
    let stats = disk.store_stats.expect("disk solve reports store stats");
    assert!(stats.evictions > 0, "n=200 run must churn the cache (budget {budget})");
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn disk_checkpoints_reference_the_store_and_resume_bitwise() {
    let n = 32;
    let inst = MetricNearnessInstance::random(n, 2.0, 11);
    let strategy = Strategy::Active { sweep_every: 3, forget_after: 1 };
    let base = NearnessOpts {
        check_every: 2,
        tol_violation: 1e-12,
        threads: 2,
        tile: 5,
        strategy,
        checkpoint_every: 2,
        ..Default::default()
    };
    let budget = 1 << 12;

    // Uninterrupted references, memory and disk.
    let full_opts = NearnessOpts { max_passes: 9, ..base };
    let (mem_ref, _) = solve_collecting(&inst, &full_opts, &StoreCfg::mem(), None);
    let dir_ref = tmp_dir("ckpt_ref");
    let (disk_ref, _) =
        solve_collecting(&inst, &full_opts, &StoreCfg::disk(&dir_ref, budget), None);
    assert_same_solution(&mem_ref, &disk_ref, "uninterrupted disk run");

    // Interrupt at pass 4: the emitted states must reference the store
    // instead of re-serializing x.
    let dir = tmp_dir("ckpt_resume");
    let cfg = StoreCfg::disk(&dir, budget);
    let half_opts = NearnessOpts { max_passes: 4, ..base };
    let (_half, states) = solve_collecting(&inst, &half_opts, &cfg, None);
    let last = states.last().expect("checkpoints were emitted");
    assert_eq!(last.pass, 4);
    assert!(last.x_external, "disk checkpoints must reference the store");
    assert!(last.x.is_empty(), "external checkpoints must not inline x");
    for st in &states[..states.len() - 1] {
        assert!(st.x_external, "every disk checkpoint references the store");
    }
    // The state survives its byte format (save -> load).
    let mut bytes = Vec::new();
    last.save(&mut bytes).expect("save");
    let reloaded = SolverState::load(&mut bytes.as_slice()).expect("load");
    assert_eq!(*last, reloaded);

    // Resume against the same store: lands bitwise on the references.
    let (resumed, _) = solve_collecting(&inst, &full_opts, &cfg, Some(&reloaded));
    assert_same_solution(&mem_ref, &resumed, "interrupt/resume vs uninterrupted");

    let _ = std::fs::remove_dir_all(dir);
    let _ = std::fs::remove_dir_all(dir_ref);
}

#[test]
fn inline_checkpoint_seeds_a_disk_resume() {
    // A classic (inline-x) checkpoint can move a solve onto the disk
    // store mid-flight; the combined run still matches the
    // uninterrupted in-memory run bitwise.
    let n = 26;
    let inst = MetricNearnessInstance::random(n, 2.0, 23);
    let base = NearnessOpts {
        check_every: 0,
        threads: 2,
        tile: 4,
        strategy: Strategy::Full,
        checkpoint_every: 3,
        ..Default::default()
    };
    let (mem_ref, _) = solve_collecting(
        &inst,
        &NearnessOpts { max_passes: 8, ..base },
        &StoreCfg::mem(),
        None,
    );
    let (_, states) = solve_collecting(
        &inst,
        &NearnessOpts { max_passes: 3, ..base },
        &StoreCfg::mem(),
        None,
    );
    let st = states.last().expect("checkpoint emitted");
    assert!(!st.x_external);
    let dir = tmp_dir("inline_to_disk");
    let (resumed, _) = solve_collecting(
        &inst,
        &NearnessOpts { max_passes: 8, ..base },
        &StoreCfg::disk(&dir, 1 << 11),
        Some(st),
    );
    assert_same_solution(&mem_ref, &resumed, "inline checkpoint -> disk resume");
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn fresh_solve_refuses_to_overwrite_an_existing_store() {
    // An x.tiles on disk may be the only copy of an earlier run's
    // iterate; a fresh (non-resuming) solve must refuse to clobber it.
    let n = 18;
    let inst = MetricNearnessInstance::random(n, 2.0, 97);
    let opts = NearnessOpts {
        max_passes: 3,
        check_every: 0,
        threads: 1,
        tile: 4,
        strategy: Strategy::Full,
        ..Default::default()
    };
    let dir = tmp_dir("no_clobber");
    let cfg = StoreCfg::disk(&dir, 1 << 11);
    let (first, _) = solve_collecting(&inst, &opts, &cfg, None);
    let err = nearness::solve_stored(&inst, &opts, &cfg, None, &mut |_| {})
        .expect_err("second fresh solve must refuse the existing store");
    assert!(
        format!("{err:?}").contains("refusing to overwrite"),
        "error should explain the refusal: {err:?}"
    );
    // The original file is untouched and still matches the first run.
    let winv: Vec<f64> = inst.w.as_slice().iter().map(|&v| 1.0 / v).collect();
    let store = DiskStore::open(&cfg.x_path(), 1 << 11, winv).expect("still opens");
    let mut survived = metric_proj::matrix::PackedSym::zeros(n);
    survived.as_mut_slice().copy_from_slice(&store.read_full().expect("read"));
    assert_eq!(survived, first.x);
    drop(store);
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn mem_resume_of_an_external_checkpoint_is_refused() {
    let n = 20;
    let inst = MetricNearnessInstance::random(n, 2.0, 31);
    let opts = NearnessOpts {
        max_passes: 4,
        check_every: 0,
        threads: 1,
        tile: 4,
        strategy: Strategy::Full,
        checkpoint_every: 2,
        ..Default::default()
    };
    let dir = tmp_dir("mem_refuse");
    let (_, states) = solve_collecting(&inst, &opts, &StoreCfg::disk(&dir, 1 << 11), None);
    let st = states.last().expect("checkpoint emitted");
    assert!(st.x_external);
    let err = nearness::solve_stored(&inst, &opts, &StoreCfg::mem(), Some(st), &mut |_| {})
        .expect_err("memory backend must refuse an external-x checkpoint");
    assert!(
        format!("{err:?}").contains("external"),
        "error should explain the external reference: {err:?}"
    );
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
#[allow(unused_unsafe)]
fn corrupted_truncated_or_drifted_stores_are_refused_on_resume() {
    let n = 22;
    let inst = MetricNearnessInstance::random(n, 2.0, 57);
    let opts = NearnessOpts {
        max_passes: 4,
        check_every: 0,
        threads: 1,
        tile: 4,
        strategy: Strategy::Active { sweep_every: 2, forget_after: 1 },
        checkpoint_every: 2,
        ..Default::default()
    };
    let dir = tmp_dir("refuse");
    let cfg = StoreCfg::disk(&dir, 1 << 11);
    let (_, states) = solve_collecting(&inst, &opts, &cfg, None);
    let st = states.last().expect("checkpoint emitted").clone();
    let path = cfg.x_path();
    let pristine = std::fs::read(&path).expect("store file exists");
    let resume = |cfg: &StoreCfg| {
        nearness::solve_stored(
            &inst,
            &NearnessOpts { max_passes: 8, ..opts },
            cfg,
            Some(&st),
            &mut |_| {},
        )
    };

    // Sanity: the pristine pair resumes.
    assert!(resume(&cfg).is_ok(), "pristine store must resume");
    std::fs::write(&path, &pristine).expect("restore");

    // Data bit flip -> block checksum rejects at open.
    let mut bad = pristine.clone();
    let last = bad.len() - 5;
    bad[last] ^= 0x20;
    std::fs::write(&path, &bad).expect("write");
    assert!(resume(&cfg).is_err(), "corrupted store must be refused");

    // Truncation -> size check rejects at open.
    std::fs::write(&path, &pristine[..pristine.len() / 2]).expect("write");
    assert!(resume(&cfg).is_err(), "truncated store must be refused");

    // Drift: restore the file, then advance its content through a
    // legitimate lease (valid checksums, unchanged stamp). The
    // fingerprint no longer matches the checkpoint -> refused.
    std::fs::write(&path, &pristine).expect("restore");
    {
        let winv: Vec<f64> = inst.w.as_slice().iter().map(|&v| 1.0 / v).collect();
        let store = DiskStore::open(&path, 1 << 11, winv).expect("reopen");
        let schedule = Schedule::new(n, 4);
        let tile = schedule.waves()[0][0];
        let mut scratch = TileScratch::default();
        // SAFETY: single thread owns the tile.
        unsafe {
            store.with_tile(&tile, &mut scratch, &mut |x, cols, _| {
                let p = cols[tile.i_lo] + (tile.k_lo - tile.i_lo - 1);
                // SAFETY: in-bounds lease addressing, single thread.
                unsafe { x.set(p, x.get(p) + 0.125) };
            });
        }
        store.flush().expect("flush");
    }
    let err = resume(&cfg).expect_err("drifted store must be refused");
    assert!(
        format!("{err:?}").contains("stamp"),
        "error should mention the stamp mismatch: {err:?}"
    );

    let _ = std::fs::remove_dir_all(dir);
}
