//! Checkpoint acceptance tests: save→load bit-identity mid-solve for
//! every strategy, resume-equivalence (interrupt at pass `t`, resume,
//! land bitwise on the uninterrupted run), and rejection of bad bytes.

use metric_proj::instance::metric_nearness::MetricNearnessInstance;
use metric_proj::instance::CcLpInstance;
use metric_proj::solver::checkpoint::{CheckpointError, SolverState};
use metric_proj::solver::nearness::{self, NearnessOpts};
use metric_proj::solver::{dykstra_parallel, dykstra_serial, SolveOpts, Strategy};

fn cc_inst(seed: u64) -> CcLpInstance {
    CcLpInstance::random(16, 0.5, 0.8, 1.6, seed)
}

/// Run to `max_passes` and return the final-state checkpoint alongside
/// the solution (checkpoint_every = usize::MAX emits only the final
/// state).
fn cc_run_with_final_state(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    serial: bool,
) -> (metric_proj::solver::Solution, SolverState) {
    let opts = SolveOpts { checkpoint_every: usize::MAX, ..*opts };
    let mut last = None;
    let sink = &mut |s: &SolverState| last = Some(s.clone());
    let sol = if serial {
        dykstra_serial::solve_checkpointed(inst, &opts, None, sink).unwrap()
    } else {
        dykstra_parallel::solve_checkpointed(inst, &opts, None, sink).unwrap()
    };
    (sol, last.expect("final state emitted"))
}

/// Serialize then deserialize — the state must survive the byte format
/// exactly (this is what makes resume-from-disk equal resume-from-RAM).
fn through_bytes(st: &SolverState) -> SolverState {
    let mut bytes = Vec::new();
    st.save(&mut bytes).unwrap();
    let back = SolverState::load(&mut bytes.as_slice()).unwrap();
    assert_eq!(*st, back, "save→load must be bit-identical");
    back
}

#[test]
fn save_load_is_bit_identical_mid_solve_for_all_strategies() {
    let inst = cc_inst(3);
    let near = MetricNearnessInstance::random(15, 2.0, 4);
    let strategies = [
        ("full", Strategy::Full),
        ("active", Strategy::Active { sweep_every: 3, forget_after: 1 }),
    ];
    for (label, strategy) in strategies {
        // CC, parallel driver (dispatches to the active driver as needed).
        let opts = SolveOpts {
            max_passes: 7,
            threads: 2,
            tile: 4,
            strategy,
            checkpoint_every: 2,
            ..Default::default()
        };
        let mut states = Vec::new();
        dykstra_parallel::solve_checkpointed(&inst, &opts, None, &mut |s| {
            states.push(s.clone())
        })
        .unwrap();
        assert!(states.len() >= 3, "{label}: expected mid-solve snapshots");
        for st in &states {
            through_bytes(st);
        }
        // Nearness driver.
        let nopts = NearnessOpts {
            max_passes: 6,
            threads: 2,
            tile: 4,
            check_every: 0,
            strategy,
            checkpoint_every: 2,
            ..Default::default()
        };
        let mut states = Vec::new();
        nearness::solve_checkpointed(&near, &nopts, None, &mut |s| states.push(s.clone()))
            .unwrap();
        assert!(states.len() >= 3, "{label} nearness: expected mid-solve snapshots");
        for st in &states {
            through_bytes(st);
        }
    }
    // Serial driver (full only).
    let opts = SolveOpts { max_passes: 6, checkpoint_every: 2, ..Default::default() };
    let mut states = Vec::new();
    dykstra_serial::solve_checkpointed(&inst, &opts, None, &mut |s| states.push(s.clone()))
        .unwrap();
    assert!(states.len() >= 3);
    for st in &states {
        through_bytes(st);
    }
}

/// ISSUE acceptance: for each strategy, solve interrupted at pass `t`
/// then resumed equals the uninterrupted solve bitwise at the same pass
/// count.
#[test]
fn resume_equivalence_serial() {
    let inst = cc_inst(11);
    let base = SolveOpts { max_passes: 10, check_every: 0, ..Default::default() };
    let full = dykstra_serial::solve(&inst, &base);
    for t in [1usize, 4, 9] {
        let interrupted = SolveOpts { max_passes: t, ..base };
        let (_, st) = cc_run_with_final_state(&inst, &interrupted, true);
        assert_eq!(st.pass, t as u64);
        let st = through_bytes(&st);
        let resumed = dykstra_serial::resume(&inst, &base, &st).unwrap();
        assert_eq!(resumed.passes, full.passes, "t={t}");
        assert_eq!(resumed.x, full.x, "t={t}: x diverged");
        assert_eq!(resumed.f, full.f, "t={t}: f diverged");
        assert_eq!(resumed.nnz_duals, full.nnz_duals, "t={t}");
        assert_eq!(resumed.metric_visits, full.metric_visits, "t={t}");
        assert_eq!(
            resumed.residuals.max_violation, full.residuals.max_violation,
            "t={t}: residuals diverged"
        );
    }
}

#[test]
fn resume_equivalence_parallel_even_across_thread_counts() {
    let inst = cc_inst(13);
    let base =
        SolveOpts { max_passes: 9, check_every: 0, threads: 3, tile: 3, ..Default::default() };
    let full = dykstra_parallel::solve(&inst, &base);
    for t in [2usize, 5] {
        let interrupted = SolveOpts { max_passes: t, ..base };
        let (_, st) = cc_run_with_final_state(&inst, &interrupted, false);
        let st = through_bytes(&st);
        // Resume with the saving thread count AND a different one: pass
        // results are bitwise p-independent, so both must land exactly
        // on the uninterrupted run.
        for threads in [3usize, 1, 5] {
            let resumed =
                dykstra_parallel::resume(&inst, &SolveOpts { threads, ..base }, &st).unwrap();
            assert_eq!(resumed.x, full.x, "t={t} p={threads}: x diverged");
            assert_eq!(resumed.f, full.f, "t={t} p={threads}: f diverged");
            assert_eq!(resumed.nnz_duals, full.nnz_duals, "t={t} p={threads}");
            assert_eq!(resumed.metric_visits, full.metric_visits, "t={t} p={threads}");
        }
    }
}

#[test]
fn resume_equivalence_active() {
    let inst = cc_inst(17);
    let strategy = Strategy::Active { sweep_every: 4, forget_after: 2 };
    let base = SolveOpts {
        max_passes: 14,
        check_every: 0,
        threads: 2,
        tile: 3,
        strategy,
        ..Default::default()
    };
    let full = dykstra_parallel::solve(&inst, &base);
    // Interrupt both right after a sweep (t = 5) and mid-cycle between
    // sweeps (t = 6, 7) — the saved membership must carry the forget
    // streaks for the continuation to forget on the same schedule.
    for t in [5usize, 6, 7, 12] {
        let interrupted = SolveOpts { max_passes: t, ..base };
        let (_, st) = cc_run_with_final_state(&inst, &interrupted, false);
        let st = through_bytes(&st);
        for threads in [2usize, 4] {
            let resumed =
                dykstra_parallel::resume(&inst, &SolveOpts { threads, ..base }, &st).unwrap();
            assert_eq!(resumed.x, full.x, "t={t} p={threads}: x diverged");
            assert_eq!(resumed.f, full.f, "t={t} p={threads}: f diverged");
            assert_eq!(resumed.nnz_duals, full.nnz_duals, "t={t} p={threads}");
            assert_eq!(resumed.metric_visits, full.metric_visits, "t={t} p={threads}");
            assert_eq!(resumed.active_triplets, full.active_triplets, "t={t} p={threads}");
        }
    }
}

#[test]
fn resume_equivalence_nearness_full_and_active() {
    let inst = MetricNearnessInstance::random(14, 2.0, 7);
    for strategy in
        [Strategy::Full, Strategy::Active { sweep_every: 3, forget_after: 1 }]
    {
        let base = NearnessOpts {
            max_passes: 10,
            check_every: 0,
            threads: 2,
            tile: 3,
            strategy,
            ..Default::default()
        };
        let full = nearness::solve(&inst, &base);
        for t in [2usize, 5, 8] {
            let interrupted =
                NearnessOpts { max_passes: t, checkpoint_every: usize::MAX, ..base };
            let mut last = None;
            nearness::solve_checkpointed(&inst, &interrupted, None, &mut |s| {
                last = Some(s.clone())
            })
            .unwrap();
            let st = through_bytes(&last.unwrap());
            let resumed = nearness::resume(&inst, &base, &st).unwrap();
            assert_eq!(resumed.x, full.x, "{strategy:?} t={t}: x diverged");
            assert_eq!(resumed.metric_visits, full.metric_visits, "{strategy:?} t={t}");
            assert_eq!(resumed.passes, full.passes, "{strategy:?} t={t}");
            assert_eq!(
                resumed.active_triplets, full.active_triplets,
                "{strategy:?} t={t}"
            );
        }
    }
}

/// Early-stopping runs also resume sensibly: the resumed run continues
/// from the saved pass count and its checks pick up the saved cadence.
#[test]
fn resume_continues_convergence_bookkeeping() {
    let inst = cc_inst(23);
    let strategy = Strategy::Active { sweep_every: 3, forget_after: 1 };
    let base = SolveOpts {
        max_passes: 20_000,
        check_every: 2,
        tol_violation: 1e-7,
        tol_gap: 1e30,
        threads: 2,
        tile: 3,
        strategy,
        ..Default::default()
    };
    let full = dykstra_parallel::solve(&inst, &base);
    assert!(full.passes < 20_000, "must converge for this test to bite");
    let t = full.passes / 2;
    let (_, st) = cc_run_with_final_state(&inst, &SolveOpts { max_passes: t, ..base }, false);
    let st = through_bytes(&st);
    let resumed = dykstra_parallel::resume(&inst, &base, &st).unwrap();
    assert_eq!(resumed.passes, full.passes, "resumed run must stop at the same pass");
    assert_eq!(resumed.x, full.x);
    assert_eq!(resumed.residuals.max_violation, full.residuals.max_violation);
}

/// Cross-strategy portability: a state saved by the full solver seeds
/// the active driver (membership derived from nonzero duals) and vice
/// versa. Not bitwise — the visit schedules differ — but both must
/// converge to the same optimum.
#[test]
fn cross_strategy_resume_converges() {
    let inst = cc_inst(29);
    let active = Strategy::Active { sweep_every: 4, forget_after: 2 };
    let mk = |strategy: Strategy, max_passes: usize| SolveOpts {
        max_passes,
        check_every: 0,
        threads: 2,
        tile: 3,
        strategy,
        ..Default::default()
    };
    // full -> active
    let (_, full_state) = cc_run_with_final_state(&inst, &mk(Strategy::Full, 6), false);
    let resumed = dykstra_parallel::resume(&inst, &mk(active, 2000), &full_state).unwrap();
    // active -> full
    let (_, act_state) = cc_run_with_final_state(&inst, &mk(active, 6), false);
    let resumed2 =
        dykstra_parallel::resume(&inst, &mk(Strategy::Full, 2000), &act_state).unwrap();
    let reference = dykstra_parallel::solve(&inst, &mk(Strategy::Full, 2000));
    for (label, sol) in [("full->active", &resumed), ("active->full", &resumed2)] {
        let mut worst = 0.0f64;
        for (i, j, v) in reference.x.iter_pairs() {
            worst = worst.max((v - sol.x.get(i, j)).abs());
        }
        assert!(worst < 1e-4, "{label}: optima differ by {worst}");
    }
}

#[test]
fn file_roundtrip_and_rejection_of_bad_files() {
    let inst = cc_inst(31);
    let opts = SolveOpts { max_passes: 4, ..Default::default() };
    let (_, st) = cc_run_with_final_state(&inst, &opts, true);
    let dir = std::env::temp_dir().join("metric_proj_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("state.ckpt");
    st.save_path(&path).unwrap();
    let back = SolverState::load_path(&path).unwrap();
    assert_eq!(st, back);
    back.validate_cc(&inst, &opts).unwrap();

    // Truncated file -> error, not panic.
    let bytes = std::fs::read(&path).unwrap();
    let cut = dir.join("truncated.ckpt");
    std::fs::write(&cut, &bytes[..bytes.len() / 2]).unwrap();
    assert!(matches!(SolverState::load_path(&cut), Err(CheckpointError::Corrupt(_))));

    // Flipped byte in the middle -> checksum failure.
    let mut bad = bytes.clone();
    let mid = bad.len() / 2;
    bad[mid] ^= 0xFF;
    let corrupt = dir.join("corrupt.ckpt");
    std::fs::write(&corrupt, &bad).unwrap();
    assert!(matches!(SolverState::load_path(&corrupt), Err(CheckpointError::Corrupt(_))));

    // Wrong magic -> BadMagic.
    let mut nonsense = bytes.clone();
    nonsense[0] = b'!';
    let junk = dir.join("junk.ckpt");
    std::fs::write(&junk, &nonsense).unwrap();
    assert!(matches!(SolverState::load_path(&junk), Err(CheckpointError::BadMagic)));

    // Resuming against the wrong instance -> Mismatch before any work.
    let other = cc_inst(32);
    assert!(matches!(
        dykstra_serial::resume(&other, &opts, &st),
        Err(e) if e.to_string().contains("checkpoint mismatch")
    ));
}
