//! Telemetry acceptance tests (ISSUE 6).
//!
//! The recorder contract: tracing is pure observation. A solve run with
//! a [`JsonlRecorder`] attached must land **bitwise** on the untraced
//! ([`NullRecorder`]) solution — same `x`, duals, pass counts, and work
//! accounting — across thread counts and strategies. A disk-backed
//! active CC solve must emit a parseable JSONL stream covering every
//! event family (passes, phases, sweeps, active set, residuals, store
//! I/O, footer), each line surviving a parse → re-serialize round trip,
//! and the `report` renderer must summarize it.
//!
//! Thread counts marked with [`env_threads`] honor the CI matrix's
//! `METRIC_PROJ_TEST_THREADS` override — results are bitwise
//! thread-count independent, so any override keeps the assertions valid.

use metric_proj::instance::metric_nearness::MetricNearnessInstance;
use metric_proj::instance::CcLpInstance;
use metric_proj::matrix::store::StoreCfg;
use metric_proj::solver::nearness::{self, NearnessOpts};
use metric_proj::solver::{dykstra_parallel, SolveOpts, Strategy};
use metric_proj::telemetry::{report, Event, JsonlRecorder, NullRecorder, PassKind, PhaseName};
use metric_proj::util::parallel::env_threads;
use std::collections::BTreeSet;
use std::path::PathBuf;

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("metric_proj_telemetry_{tag}_{}.jsonl", std::process::id()))
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("metric_proj_telemetry_{tag}_{}", std::process::id()))
}

#[test]
fn traced_cc_solve_is_bitwise_identical_to_untraced() {
    let cases = [
        (1usize, Strategy::Full),
        (env_threads(3), Strategy::Full),
        (1, Strategy::Active { sweep_every: 3, forget_after: 1 }),
        (env_threads(3), Strategy::Active { sweep_every: 3, forget_after: 1 }),
    ];
    for (idx, &(threads, strategy)) in cases.iter().enumerate() {
        let inst = CcLpInstance::random(24, 0.5, 0.8, 1.6, 61 + idx as u64);
        let opts = SolveOpts {
            max_passes: 8,
            check_every: 3,
            tol_violation: 1e-12,
            tol_gap: 1e-12,
            threads,
            tile: 5,
            strategy,
            ..Default::default()
        };
        let ctx = format!("case {idx}: p={threads} {strategy:?}");
        let plain = dykstra_parallel::solve_traced(
            &inst,
            &opts,
            &StoreCfg::mem(),
            None,
            &mut |_| {},
            &NullRecorder,
        )
        .expect("untraced solve");
        let path = tmp_path(&format!("cc{idx}"));
        let rec = JsonlRecorder::create(&path).expect("create trace");
        let traced = dykstra_parallel::solve_traced(
            &inst,
            &opts,
            &StoreCfg::mem(),
            None,
            &mut |_| {},
            &rec,
        )
        .expect("traced solve");
        rec.finish().expect("flush trace");
        assert_eq!(plain.x, traced.x, "{ctx}: x diverged under tracing");
        assert_eq!(plain.f, traced.f, "{ctx}: slacks diverged under tracing");
        assert_eq!(plain.passes, traced.passes, "{ctx}: pass counts diverged");
        assert_eq!(plain.nnz_duals, traced.nnz_duals, "{ctx}: dual counts diverged");
        assert_eq!(plain.metric_visits, traced.metric_visits, "{ctx}: work diverged");
        assert_eq!(
            plain.residuals.max_violation, traced.residuals.max_violation,
            "{ctx}: violation diverged"
        );
        assert_eq!(plain.residuals.rel_gap, traced.residuals.rel_gap, "{ctx}: gap diverged");
        // the counters snapshot mirrors the solution's own fields
        let c = traced.counters();
        assert_eq!(c.passes, traced.passes as u64, "{ctx}");
        assert_eq!(c.metric_visits, traced.metric_visits, "{ctx}");
        assert_eq!(c.nnz_duals, traced.nnz_duals as u64, "{ctx}");
        let _ = std::fs::remove_file(path);
    }
}

#[test]
fn traced_nearness_solve_is_bitwise_identical_to_untraced() {
    for (idx, &(threads, strategy)) in [
        (1usize, Strategy::Full),
        (env_threads(3), Strategy::Active { sweep_every: 3, forget_after: 1 }),
    ]
    .iter()
    .enumerate()
    {
        let inst = MetricNearnessInstance::random(26, 2.0, 17 + idx as u64);
        let opts = NearnessOpts {
            max_passes: 8,
            check_every: 3,
            tol_violation: 1e-12,
            threads,
            tile: 5,
            strategy,
            ..Default::default()
        };
        let ctx = format!("case {idx}: p={threads} {strategy:?}");
        let plain = nearness::solve_traced(
            &inst,
            &opts,
            &StoreCfg::mem(),
            None,
            &mut |_| {},
            &NullRecorder,
        )
        .expect("untraced solve");
        let path = tmp_path(&format!("near{idx}"));
        let rec = JsonlRecorder::create(&path).expect("create trace");
        let traced =
            nearness::solve_traced(&inst, &opts, &StoreCfg::mem(), None, &mut |_| {}, &rec)
                .expect("traced solve");
        rec.finish().expect("flush trace");
        assert_eq!(plain.x, traced.x, "{ctx}: x diverged under tracing");
        assert_eq!(plain.passes, traced.passes, "{ctx}: pass counts diverged");
        assert_eq!(plain.metric_visits, traced.metric_visits, "{ctx}: work diverged");
        assert_eq!(plain.max_violation, traced.max_violation, "{ctx}: violation diverged");
        assert_eq!(plain.objective, traced.objective, "{ctx}: objective diverged");
        let _ = std::fs::remove_file(path);
    }
}

/// The ISSUE's trace-coverage acceptance: a disk-backed active-strategy
/// CC solve with a trace attached emits parseable JSONL covering passes,
/// phases, sweeps, the active set, residuals, store I/O, and the footer
/// — and every line survives parse → re-serialize unchanged.
#[test]
fn disk_backed_active_cc_trace_covers_the_schema() {
    let inst = CcLpInstance::random(26, 0.5, 0.8, 1.6, 77);
    let opts = SolveOpts {
        max_passes: 7,
        check_every: 3,
        tol_violation: 1e-12,
        tol_gap: 1e-12,
        threads: env_threads(2),
        tile: 5,
        strategy: Strategy::Active { sweep_every: 3, forget_after: 1 },
        checkpoint_every: 3,
        ..Default::default()
    };
    let dir = tmp_dir("cc_schema");
    let path = tmp_path("cc_schema");
    let rec = JsonlRecorder::create(&path).expect("create trace");
    let sol = dykstra_parallel::solve_traced(
        &inst,
        &opts,
        &StoreCfg::disk(&dir, 1 << 11),
        None,
        &mut |_| {},
        &rec,
    )
    .expect("traced disk solve");
    rec.finish().expect("flush trace");

    let text = std::fs::read_to_string(&path).expect("read trace");
    let mut kinds: BTreeSet<&'static str> = BTreeSet::new();
    let mut phase_names: BTreeSet<String> = BTreeSet::new();
    let mut pass_kinds: BTreeSet<String> = BTreeSet::new();
    let mut footer = None;
    let mut lines = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        lines += 1;
        let ev = Event::parse(line)
            .unwrap_or_else(|e| panic!("line {}: unparseable event ({e}): {line}", lineno + 1));
        assert_eq!(
            ev.to_json_line(),
            line,
            "line {}: parse -> re-serialize must round-trip",
            lineno + 1
        );
        kinds.insert(match &ev {
            Event::PassStart { kind, .. } => {
                pass_kinds.insert(format!("{kind:?}"));
                "pass_start"
            }
            Event::Phase { name, secs, visits: _, workers, .. } => {
                phase_names.insert(name.as_str().to_string());
                assert!(secs.is_finite() && *secs >= 0.0, "negative phase time");
                // Parallel phases carry one busy time per worker;
                // single-threaded scans/checkpoints carry none.
                assert!(
                    workers.is_empty() || workers.len() == opts.threads,
                    "per-worker busy times: {workers:?}"
                );
                "phase"
            }
            Event::Sweep { screened, projected, .. } => {
                assert!(projected <= screened, "hit rate above 1");
                "sweep"
            }
            Event::ActiveSet { .. } => "active_set",
            Event::Residuals { .. } => "residuals",
            Event::StoreIo { stats, .. } => {
                assert!(stats.loads > 0, "disk solve must load blocks");
                "store_io"
            }
            Event::PassEnd { .. } => "pass_end",
            Event::Warn { .. } => "warn",
            Event::Footer { counters } => {
                footer = Some(counters.clone());
                "footer"
            }
        });
    }
    assert!(lines > 0, "trace is empty");
    for required in
        ["pass_start", "phase", "sweep", "active_set", "residuals", "store_io", "pass_end", "footer"]
    {
        assert!(kinds.contains(required), "trace never emitted `{required}` (got {kinds:?})");
    }
    for required in ["metric", "pair", "sweep", "residual-scan", "checkpoint"] {
        assert!(
            phase_names.contains(required),
            "trace never emitted phase `{required}` (got {phase_names:?})"
        );
    }
    assert!(
        pass_kinds.contains(&format!("{:?}", PassKind::Sweep))
            && pass_kinds.contains(&format!("{:?}", PassKind::Cheap)),
        "active run must mark sweep and cheap passes (got {pass_kinds:?})"
    );
    // The footer agrees with the returned solution's counters.
    let footer = footer.expect("footer captured");
    assert_eq!(footer.passes, sol.passes as u64);
    assert_eq!(footer.metric_visits, sol.metric_visits);
    assert_eq!(footer.sweep_screened, sol.sweep_screened);
    assert_eq!(footer.sweep_projected, sol.sweep_projected);
    assert_eq!(footer.nnz_duals, sol.nnz_duals as u64);
    assert_eq!(footer.store, sol.store_stats);
    assert!(!footer.phase_secs.is_empty(), "footer carries the phase breakdown");
    assert!(!footer.worker_busy_secs.is_empty(), "footer carries worker busy times");

    // `report` digests the same file.
    let path_str = path.display().to_string();
    let summary = report::render_files(&[path_str.as_str()]).expect("report renders");
    assert!(summary.contains("passes"), "report should summarize passes:\n{summary}");
    assert!(summary.contains("metric"), "report should show the phase table:\n{summary}");

    let _ = std::fs::remove_file(path);
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn phase_names_match_the_documented_schema() {
    // docs/OBSERVABILITY.md documents these exact spellings; the report
    // and any external consumer key on them.
    let spellings: Vec<&str> = [
        PhaseName::Metric,
        PhaseName::Pair,
        PhaseName::ResidualScan,
        PhaseName::Sweep,
        PhaseName::Checkpoint,
    ]
    .iter()
    .map(|p| p.as_str())
    .collect();
    assert_eq!(spellings, ["metric", "pair", "residual-scan", "sweep", "checkpoint"]);
}
