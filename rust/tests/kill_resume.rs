//! Crash-recovery subprocess tests against the real `metric-proj`
//! binary: SIGKILL a disk-backed solve mid-pass and resume it; SIGTERM
//! one running with `--on-interrupt checkpoint` and watch it exit
//! cleanly. Both recovered runs must land **bitwise identical** to an
//! uninterrupted reference — the invariant the checkpoint subsystem and
//! the wave schedule's determinism promise together.
//!
//! The victim runs are slowed with the fault plan's deterministic
//! latency injection (`latency=1.0`) so the kill reliably lands while
//! passes are still in flight; latency spikes change wall-clock only,
//! never values, so the reference runs skip them.

#![cfg(unix)]

use metric_proj::matrix::store::DiskStore;
use metric_proj::solver::checkpoint::SolverState;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

const BIN: &str = env!("CARGO_BIN_EXE_metric-proj");
const N: usize = 100;

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("metric_proj_kill_{tag}_{}", std::process::id()))
}

/// `nearness` invocation shared by every run of one scenario: same
/// instance (seed), same schedule, same pass budget.
fn nearness_cmd(store_dir: &Path, ck: &Path) -> Command {
    let mut cmd = Command::new(BIN);
    cmd.args(["nearness", "--n", &N.to_string(), "--seed", "7"]);
    cmd.args(["--passes", "6", "--threads", "2", "--tile", "20"]);
    cmd.args(["--store", "disk", "--store-budget-mb", "1"]);
    cmd.arg("--store-dir").arg(store_dir);
    cmd.arg("--checkpoint").arg(ck);
    cmd.stdout(Stdio::piped()).stderr(Stdio::piped());
    cmd
}

/// Block until `ck` holds a loadable state with `pass >= 1` (the victim
/// finished at least one pass and checkpointed it), or panic after a
/// generous timeout. Checkpoint writes are tmp+rename atomic, so a
/// midway load never sees torn bytes.
fn wait_for_first_checkpoint(ck: &Path, child: &mut Child) {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        if let Ok(st) = SolverState::load_path(ck) {
            if st.pass >= 1 {
                return;
            }
        }
        if let Ok(Some(status)) = child.try_wait() {
            // The victim outran us — that run degenerates to a plain
            // resume-from-final, which keeps the equality assertions
            // valid, just less interesting. Only a *failed* exit is a bug.
            assert!(status.success(), "victim exited early with {status}");
            return;
        }
        assert!(Instant::now() < deadline, "no checkpoint appeared within 120s");
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn wait_with_timeout(child: &mut Child, secs: u64) -> std::process::ExitStatus {
    let deadline = Instant::now() + Duration::from_secs(secs);
    loop {
        if let Ok(Some(status)) = child.try_wait() {
            return status;
        }
        if Instant::now() >= deadline {
            let _ = child.kill();
            panic!("subprocess did not exit within {secs}s");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// The store's packed payload, read back through the verified open path.
fn store_payload(store_dir: &Path) -> Vec<f64> {
    let winv = vec![1.0; N * (N - 1) / 2];
    let store = DiskStore::open(&store_dir.join("x.tiles"), 1 << 20, winv)
        .expect("finished store opens clean");
    store.read_full().expect("payload reads")
}

fn assert_same_final_state(ref_ck: &Path, ck: &Path, ref_store: &Path, store: &Path, ctx: &str) {
    let a = SolverState::load_path(ref_ck).expect("reference checkpoint loads");
    let b = SolverState::load_path(ck).expect("recovered checkpoint loads");
    assert_eq!(a, b, "{ctx}: final checkpoint states diverged");
    assert_eq!(
        store_payload(ref_store),
        store_payload(store),
        "{ctx}: final iterates diverged"
    );
}

#[test]
fn sigkill_mid_pass_resumes_bitwise_identical() {
    let root = tmp_dir("sigkill");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).expect("mkdir");
    let (ref_store, ref_ck) = (root.join("ref_store"), root.join("ref.ckpt"));
    let (store, ck) = (root.join("store"), root.join("run.ckpt"));

    // Uninterrupted reference.
    let out = nearness_cmd(&ref_store, &ref_ck).output().expect("spawn reference");
    assert!(
        out.status.success(),
        "reference run failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );

    // Victim: checkpoint every pass, latency-throttled, killed hard
    // after its first checkpoint lands (a mid-pass kill may tear the
    // live store file; the checkpoint's `.ckpt` snapshot must cover it).
    let mut victim = nearness_cmd(&store, &ck)
        .args(["--checkpoint-every", "1"])
        .args(["--fault-plan", "seed=1,latency=1.0,latency-ms=50"])
        .spawn()
        .expect("spawn victim");
    wait_for_first_checkpoint(&ck, &mut victim);
    let _ = victim.kill();
    let _ = victim.wait();

    // Resume (no latency this time) and land on the reference bitwise.
    let out = nearness_cmd(&store, &ck)
        .args(["--checkpoint-every", "1"])
        .arg("--resume")
        .arg(&ck)
        .output()
        .expect("spawn resume");
    assert!(
        out.status.success(),
        "resume after SIGKILL failed:\n{}\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("resume    : from pass"), "resume banner missing:\n{stdout}");

    assert_same_final_state(&ref_ck, &ck, &ref_store, &store, "SIGKILL/resume");
    let _ = std::fs::remove_dir_all(root);
}

#[test]
fn sigterm_with_on_interrupt_checkpoint_exits_cleanly_and_resumes() {
    let root = tmp_dir("sigterm");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).expect("mkdir");
    let (ref_store, ref_ck) = (root.join("ref_store"), root.join("ref.ckpt"));
    let (store, ck) = (root.join("store"), root.join("run.ckpt"));

    let out = nearness_cmd(&ref_store, &ref_ck).output().expect("spawn reference");
    assert!(
        out.status.success(),
        "reference run failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );

    // Victim: TERM must finish the pass in flight, checkpoint, and exit
    // zero — what a service manager's stop expects.
    let mut victim = nearness_cmd(&store, &ck)
        .args(["--checkpoint-every", "1", "--on-interrupt", "checkpoint"])
        .args(["--fault-plan", "seed=1,latency=1.0,latency-ms=50"])
        .spawn()
        .expect("spawn victim");
    wait_for_first_checkpoint(&ck, &mut victim);
    let term = Command::new("kill")
        .args(["-TERM", &victim.id().to_string()])
        .status()
        .expect("send SIGTERM");
    assert!(term.success(), "kill -TERM failed");
    let status = wait_with_timeout(&mut victim, 120);
    let mut stdout = String::new();
    if let Some(mut h) = victim.stdout.take() {
        use std::io::Read;
        let _ = h.read_to_string(&mut stdout);
    }
    assert!(status.success(), "TERM-interrupted run must exit 0, got {status}\n{stdout}");
    assert!(
        stdout.contains("interrupted: stopped cleanly after pass"),
        "clean-interrupt banner missing:\n{stdout}"
    );
    assert!(
        stdout.contains("(state checkpointed)"),
        "the interrupt must report its checkpoint:\n{stdout}"
    );

    // The checkpointed interrupt lost no work: resume to completion.
    let out = nearness_cmd(&store, &ck)
        .args(["--checkpoint-every", "1"])
        .arg("--resume")
        .arg(&ck)
        .output()
        .expect("spawn resume");
    assert!(
        out.status.success(),
        "resume after SIGTERM failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );

    assert_same_final_state(&ref_ck, &ck, &ref_store, &store, "SIGTERM/resume");
    let _ = std::fs::remove_dir_all(root);
}
