//! Property tests for the wave schedule and the tile iterators — the
//! conflict-freeness and coverage invariants every solver (and now the
//! checkpoint redistribution) builds on.

use metric_proj::prop_assert;
use metric_proj::solver::schedule::{n_triplets, Schedule, Tile};
use metric_proj::solver::tiling::{for_each_triplet, for_each_triplet_lex};
use metric_proj::util::proptest::check;
use metric_proj::util::rng::Rng;
use std::collections::{HashMap, HashSet};

/// The three variable pairs a triplet's projections touch.
fn pairs_of(i: usize, j: usize, k: usize) -> [(usize, usize); 3] {
    [(i, j), (i, k), (j, k)]
}

/// Schedule invariant, part 1: within every wave, the `(i, j)` variable
/// pairs touched by different tiles are pairwise disjoint — the data-race
/// freedom the lock-free metric phase relies on.
#[test]
fn waves_touch_pairwise_disjoint_variable_pairs() {
    check("wave tiles touch disjoint pairs", 0x5C4ED1, 32, |rng, _| {
        let n = rng.usize_in(3, 70);
        let b = rng.usize_in(1, 14);
        let s = Schedule::new(n, b);
        for (wi, wave) in s.waves().iter().enumerate() {
            // pair -> index of the tile that touched it first
            let mut owner: HashMap<(usize, usize), usize> = HashMap::new();
            for (r, tile) in wave.iter().enumerate() {
                let mut touched = Vec::new();
                for_each_triplet(tile, b, |i, j, k| touched.extend(pairs_of(i, j, k)));
                for pair in touched {
                    if let Some(&other) = owner.get(&pair) {
                        prop_assert!(
                            other == r,
                            "n={n} b={b} wave {wi}: pair {pair:?} touched by tiles {other} and {r}"
                        );
                    } else {
                        owner.insert(pair, r);
                    }
                }
            }
        }
        Ok(())
    });
}

/// Schedule invariant, part 2: the union of all waves covers every
/// triplet `i < j < k` exactly once.
#[test]
fn waves_cover_every_triplet_exactly_once() {
    check("waves cover C(n,3) exactly once", 0x5C4ED2, 32, |rng, _| {
        let n = rng.usize_in(3, 70);
        let b = rng.usize_in(1, 14);
        let s = Schedule::new(n, b);
        let mut seen = HashSet::new();
        for wave in s.waves() {
            for tile in wave {
                let mut dup = None;
                for_each_triplet(tile, b, |i, j, k| {
                    if !seen.insert((i, j, k)) {
                        dup = Some((i, j, k));
                    }
                });
                prop_assert!(dup.is_none(), "n={n} b={b}: duplicate triplet {dup:?}");
            }
        }
        prop_assert!(
            seen.len() as u64 == n_triplets(n),
            "n={n} b={b}: covered {} of {} triplets",
            seen.len(),
            n_triplets(n)
        );
        for &(i, j, k) in &seen {
            prop_assert!(i < j && j < k && k < n, "invalid triplet ({i},{j},{k})");
        }
        Ok(())
    });
}

/// Brute-force reference for a tile's triplet set: the clipped cube
/// `{(i, j, k) : i ∈ I, k ∈ K, i < j < k}`.
fn brute_force_tile(tile: &Tile) -> HashSet<(usize, usize, usize)> {
    let mut want = HashSet::new();
    for i in tile.i_lo..tile.i_hi {
        for k in tile.k_lo..tile.k_hi {
            for j in (i + 1)..k {
                want.insert((i, j, k));
            }
        }
    }
    want
}

/// `for_each_triplet` over random (not schedule-generated) tiles visits
/// exactly the clipped cube's triplet set, without duplicates, and
/// agrees with `Tile::triplet_count`.
#[test]
fn for_each_triplet_visits_exactly_the_clipped_cube() {
    check("for_each_triplet = clipped cube", 0x7113D, 64, |rng, _| {
        let n = rng.usize_in(3, 60);
        let i_lo = rng.usize_in(0, n);
        let i_hi = rng.usize_in(i_lo, n + 1).max(i_lo + 1);
        let k_lo = rng.usize_in(0, n);
        let k_hi = rng.usize_in(k_lo, n + 1).max(k_lo + 1);
        let tile = Tile { i_lo, i_hi, k_lo, k_hi };
        let b = rng.usize_in(1, 9);
        let mut got = Vec::new();
        for_each_triplet(&tile, b, |i, j, k| got.push((i, j, k)));
        let got_set: HashSet<_> = got.iter().copied().collect();
        prop_assert!(got_set.len() == got.len(), "{tile:?} b={b}: duplicates visited");
        let want = brute_force_tile(&tile);
        prop_assert!(
            got_set == want,
            "{tile:?} b={b}: visited {} triplets, brute force finds {}",
            got_set.len(),
            want.len()
        );
        prop_assert!(
            tile.triplet_count() == got.len() as u64,
            "{tile:?}: triplet_count {} != visited {}",
            tile.triplet_count(),
            got.len()
        );
        Ok(())
    });
}

/// The cube iteration order is deterministic and identical across calls
/// (the per-worker dual stores require it), for random tiles.
#[test]
fn for_each_triplet_order_is_deterministic() {
    let mut rng = Rng::new(0xDE7E12);
    for _ in 0..50 {
        let i_lo = rng.usize_in(0, 20);
        let tile = Tile {
            i_lo,
            i_hi: i_lo + rng.usize_in(1, 6),
            k_lo: rng.usize_in(0, 25),
            k_hi: rng.usize_in(20, 30),
        };
        let b = rng.usize_in(1, 7);
        let mut a = Vec::new();
        let mut bb = Vec::new();
        for_each_triplet(&tile, b, |i, j, k| a.push((i, j, k)));
        for_each_triplet(&tile, b, |i, j, k| bb.push((i, j, k)));
        assert_eq!(a, bb);
    }
}

/// `for_each_triplet_lex` enumerates all `C(n,3)` triplets in strictly
/// increasing lexicographic order.
#[test]
fn lex_iterator_is_complete_and_lex_ordered() {
    check("for_each_triplet_lex lex order", 0x13D09, 24, |rng, _| {
        let n = rng.usize_in(0, 40);
        let mut got = Vec::new();
        for_each_triplet_lex(n, |i, j, k| got.push((i, j, k)));
        prop_assert!(
            got.len() as u64 == n_triplets(n),
            "n={n}: {} visited, want C(n,3) = {}",
            got.len(),
            n_triplets(n)
        );
        for tri in &got {
            prop_assert!(tri.0 < tri.1 && tri.1 < tri.2 && tri.2 < n, "bad {tri:?}");
        }
        for pair in got.windows(2) {
            prop_assert!(pair[0] < pair[1], "not strictly lex: {:?} then {:?}", pair[0], pair[1]);
        }
        Ok(())
    });
}

/// Composing both invariants: summing `triplet_count` over any schedule
/// equals C(n,3), and iterating with the wrong chunk size `b` still
/// visits the same *set* (chunking only reorders).
#[test]
fn chunk_size_changes_order_not_coverage() {
    let tile = Tile { i_lo: 1, i_hi: 5, k_lo: 4, k_hi: 12 };
    let reference = brute_force_tile(&tile);
    for b in 1..10 {
        let mut got = HashSet::new();
        for_each_triplet(&tile, b, |i, j, k| {
            assert!(got.insert((i, j, k)), "b={b}: duplicate");
        });
        assert_eq!(got, reference, "b={b}");
    }
}
