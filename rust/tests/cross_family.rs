//! Cross-family differential tests: the Dykstra and proximal solver
//! families must agree — within the documented tolerance bands — on
//! every seeded instance of a sweep, and the oracle must *detect* a
//! deliberately broken triangle operator (negative test). Together
//! these pin the tolerance model of `eval::cross_check`: tight enough
//! to catch a one-character kernel bug, loose enough for two honestly
//! converged but mathematically unrelated algorithms.

use metric_proj::eval::cross_check::{self, Band, CaseSpec, WeightKind};
use metric_proj::solver::nearness::{self, NearnessOpts};
use metric_proj::solver::proximal::{self, operator, ProxTuning};
use metric_proj::solver::Algorithm;
use metric_proj::telemetry::NullRecorder;
use metric_proj::util::parallel::env_threads;

/// A converged Dykstra reference for `inst`.
fn dykstra_reference(
    inst: &metric_proj::instance::metric_nearness::MetricNearnessInstance,
    threads: usize,
) -> nearness::NearnessSolution {
    nearness::solve(
        inst,
        &NearnessOpts {
            max_passes: 5000,
            check_every: 10,
            tol_violation: 1e-10,
            threads,
            ..Default::default()
        },
    )
}

#[test]
fn families_agree_on_seeded_sweep() {
    let threads = env_threads(2);
    // A trimmed version of the nightly sweep: every weight structure,
    // two sizes, fixed base seed. The nightly CI job runs the full
    // default_sweep at larger ns via `metric-proj cross-check`.
    let specs = cross_check::default_sweep(0xc405, &[8, 13]);
    assert_eq!(specs.len(), 6);
    let report = cross_check::run_sweep(&specs, threads);
    assert_eq!(report.verdicts.len(), 12, "2 members per case");
    assert!(
        report.all_pass(),
        "cross-family mismatch:\n{}",
        report.render_table()
    );
    // The verdict table is the CI artifact: it must serialize and parse.
    let json = report.to_json().to_string();
    let back = metric_proj::util::json::Json::parse(&json).unwrap();
    assert_eq!(back.get("all_pass").and_then(|v| v.as_bool()), Some(true));
}

#[test]
fn broken_kernel_is_caught_by_the_oracle() {
    let threads = env_threads(2);
    let spec = CaseSpec { n: 10, seed: 0xbad, weights: WeightKind::Unit, hi: 2.0 };
    let inst = spec.build();
    let dyk = dykstra_reference(&inst, threads);
    let band = Band::for_algorithm(Algorithm::ProxMm);

    // Control: the same entry point over the *real* operator passes.
    let real_op = operator::WaveOperator::new(inst.n, 8, threads);
    let good = proximal::solve_nearness_with(
        &inst,
        Algorithm::ProxMm,
        band.solve_tol,
        threads,
        &ProxTuning::default(),
        &real_op,
        &NullRecorder,
    )
    .expect("real operator must converge");
    let good_verdict = cross_check::judge(
        "control/real".into(),
        Algorithm::ProxMm,
        dyk.objective,
        good.objective,
        good.max_violation,
        band,
    );
    assert!(good_verdict.pass, "{good_verdict:?}");

    // Negative test: one flipped sign in the fused T'T kernel. The MM
    // solver stops on the *true* violation scan, so the broken operator
    // either never reaches tolerance or converges to a wrong point —
    // both must land far outside the band.
    let broken = operator::BrokenOperator(operator::WaveOperator::new(inst.n, 8, threads));
    let verdict = match proximal::solve_nearness_with(
        &inst,
        Algorithm::ProxMm,
        band.solve_tol,
        threads,
        &ProxTuning::default(),
        &broken,
        &NullRecorder,
    ) {
        Ok(sol) => cross_check::judge(
            "negative/broken".into(),
            Algorithm::ProxMm,
            dyk.objective,
            sol.objective,
            sol.max_violation,
            band,
        ),
        // Typed divergence is an equally valid detection.
        Err(_) => cross_check::judge(
            "negative/broken-diverged".into(),
            Algorithm::ProxMm,
            dyk.objective,
            f64::NAN,
            f64::INFINITY,
            band,
        ),
    };
    assert!(
        !verdict.pass,
        "oracle insensitive: broken T'T passed the band (rel_gap {:.3e}, viol {:.3e})",
        verdict.rel_gap,
        verdict.max_violation
    );
    // Demand real margin, not a lucky near-miss: the prototype measured
    // the broken kernel ~4 orders of magnitude outside either band.
    assert!(
        verdict.rel_gap > 10.0 * band.rel_obj_tol
            || verdict.max_violation > 10.0 * band.viol_tol
            || !verdict.obj_prox.is_finite(),
        "broken kernel too close to the band: rel_gap {:.3e}, viol {:.3e}",
        verdict.rel_gap,
        verdict.max_violation
    );
}

#[test]
fn solver_errors_become_failing_verdicts_not_panics() {
    // n = 3 with a hostile seed is fine; what we pin here is that the
    // sweep API never panics and a mismatching member yields pass=false
    // rows rather than unwinding (the nightly job depends on this to go
    // red gracefully).
    let specs = [CaseSpec { n: 3, seed: 1, weights: WeightKind::Unit, hi: 2.0 }];
    let report = cross_check::run_sweep(&specs, 1);
    assert_eq!(report.verdicts.len(), 2);
    for v in &report.verdicts {
        assert!(v.pass, "n=3 must be solvable by both members: {v:?}");
    }
}

/// Larger sweep cell for the nightly tier (ignored in tier-1: ~seconds
/// of CG at n=24 × 3 weight kinds is slow-test budget, not unit budget).
#[test]
#[ignore = "nightly: larger-n oracle sweep (run via cargo test -- --ignored)"]
fn families_agree_at_larger_n_nightly() {
    let threads = env_threads(4);
    let specs = cross_check::default_sweep(0x417, &[20, 24]);
    let report = cross_check::run_sweep(&specs, threads);
    assert!(
        report.all_pass(),
        "cross-family mismatch at larger n:\n{}",
        report.render_table()
    );
}
