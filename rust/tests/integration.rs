//! Integration tests: compose the public API across modules the way the
//! examples and the CLI do (graph -> instance -> solver -> rounding ->
//! certificates; runtime artifacts; eval harness).

use metric_proj::graph::components::largest_component;
use metric_proj::graph::datasets::Dataset;
use metric_proj::graph::generators;
use metric_proj::instance::construction::{build_cc_instance, ConstructionParams};
use metric_proj::instance::metric_nearness::{max_triangle_violation, MetricNearnessInstance};
use metric_proj::instance::{cc_objective, CcLpInstance};
use metric_proj::matrix::StoreCfg;
use metric_proj::rounding::{pivot, threshold};
use metric_proj::solver::{dykstra_parallel, nearness, SolveOpts, Strategy, SweepBackend};

#[test]
fn full_pipeline_planted_clusters_recovered() {
    // Graph with 3 planted communities -> dense CC instance -> LP ->
    // rounding must recover communities with objective matching LP bound.
    let g = generators::collaboration(45, 3, 0.9, 0, 11);
    let g = largest_component(&g);
    let inst = build_cc_instance(&g, ConstructionParams::default(), 2);
    inst.validate().unwrap();
    let opts = SolveOpts {
        max_passes: 300,
        check_every: 20,
        tol_violation: 1e-6,
        tol_gap: 1e-4,
        threads: 3,
        tile: 8,
        ..Default::default()
    };
    let sol = dykstra_parallel::solve(&inst, &opts);
    assert!(sol.residuals.max_violation < 1e-3, "violation {}", sol.residuals.max_violation);
    let lp = sol.residuals.lp_objective;
    let labels = threshold::round(&sol.x, 0.5);
    let obj = cc_objective(&inst, &labels);
    // LP is a lower bound; a good rounding is within a small factor.
    assert!(obj + 1e-9 >= lp, "LP bound violated: {obj} < {lp}");
    assert!(obj <= 2.5 * lp.max(1e-9) + 1e-6, "rounding far from bound: {obj} vs {lp}");
    let (_, obj_piv) = pivot::round_best(&sol.x, 30, 5, |l| cc_objective(&inst, l));
    assert!(obj_piv + 1e-9 >= lp);
}

/// Cross-driver agreement matrix: serial/parallel/active ×
/// mem/disk × scalar/screened, all 12 cells in one parameterized
/// table (replacing the old ad-hoc pairwise cases). Results are
/// bitwise independent of thread count, store backend, and sweep
/// backend by construction, so every cell within a strategy family
/// must match its family reference *exactly*; across families
/// (full vs active visit different constraint subsets) agreement is
/// within 1e-6. CC-LP serial-order-vs-parallel agreement is pinned
/// separately in `dykstra_parallel`'s unit tests.
#[test]
fn cross_driver_agreement_matrix() {
    let inst = MetricNearnessInstance::random(28, 2.0, 5);
    let tol = 1e-7;
    let base = nearness::NearnessOpts {
        max_passes: 4000,
        check_every: 5,
        tol_violation: tol,
        tile: 8,
        ..Default::default()
    };
    let drivers: [(&str, usize, Strategy); 3] = [
        ("serial", 1, Strategy::Full),
        ("parallel", 4, Strategy::Full),
        ("active", 4, Strategy::Active { sweep_every: 5, forget_after: 2 }),
    ];
    let stores = ["mem", "disk"];
    let backends = [SweepBackend::Scalar, SweepBackend::Screened];

    let mut full_ref: Option<nearness::NearnessSolution> = None; // serial/mem/scalar
    let mut active_ref: Option<nearness::NearnessSolution> = None;
    for (driver, threads, strategy) in drivers {
        for store in stores {
            for backend in backends {
                let label = format!("{driver}/{store}/{}", backend.name());
                let cfg = if store == "mem" {
                    StoreCfg::mem()
                } else {
                    let dir = std::env::temp_dir()
                        .join(format!("metric_proj_matrix_{driver}_{}", backend.name()));
                    let _ = std::fs::remove_dir_all(&dir);
                    StoreCfg::disk(&dir, 1 << 10)
                };
                let opts = nearness::NearnessOpts {
                    threads,
                    strategy,
                    sweep_backend: backend,
                    ..base
                };
                let sol = nearness::solve_stored(&inst, &opts, &cfg, None, &mut |_| {})
                    .unwrap_or_else(|e| panic!("{label}: solve failed: {e}"));
                assert!(sol.passes < base.max_passes, "{label}: no convergence");
                assert!(
                    sol.max_violation <= 10.0 * tol,
                    "{label}: violation {}",
                    sol.max_violation
                );
                if store == "disk" {
                    let stats = sol
                        .store_stats
                        .as_ref()
                        .unwrap_or_else(|| panic!("{label}: disk solve reports no store stats"));
                    assert!(stats.loads > 0, "{label}: disk solve never loaded a block");
                }
                if strategy.is_active() {
                    match &active_ref {
                        None => {
                            // First active cell: tolerance-compare the
                            // two families and pin the work saving.
                            let full = full_ref.as_ref().expect("full reference runs first");
                            assert!(
                                (sol.objective - full.objective).abs()
                                    <= 1e-6 * full.objective.max(1.0),
                                "{label}: objectives differ: {} vs {}",
                                sol.objective,
                                full.objective
                            );
                            assert!(
                                (sol.max_violation - full.max_violation).abs() <= 1e-6,
                                "{label}: violations differ: {} vs {}",
                                sol.max_violation,
                                full.max_violation
                            );
                            assert!(
                                sol.metric_visits < full.metric_visits,
                                "{label}: active visits {} !< full visits {}",
                                sol.metric_visits,
                                full.metric_visits
                            );
                            active_ref = Some(sol);
                        }
                        Some(r) => {
                            assert_eq!(r.x, sol.x, "{label}: active cells must agree bitwise");
                            assert_eq!(r.passes, sol.passes, "{label}: stopping pass differs");
                            assert_eq!(r.metric_visits, sol.metric_visits, "{label}");
                        }
                    }
                } else {
                    match &full_ref {
                        None => full_ref = Some(sol),
                        Some(r) => {
                            assert_eq!(r.x, sol.x, "{label}: full cells must agree bitwise");
                            assert_eq!(r.passes, sol.passes, "{label}: stopping pass differs");
                            assert_eq!(r.metric_visits, sol.metric_visits, "{label}");
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn nearness_pipeline_produces_metric() {
    let inst = MetricNearnessInstance::random(40, 2.0, 5);
    let sol = nearness::solve(
        &inst,
        &nearness::NearnessOpts {
            max_passes: 2000,
            check_every: 25,
            tol_violation: 1e-6,
            threads: 2,
            tile: 8,
            ..Default::default()
        },
    );
    assert!(sol.max_violation <= 1e-6);
    assert!(max_triangle_violation(&sol.x) <= 1e-6);
    assert!(sol.passes < 2000, "early stop expected, ran {}", sol.passes);
}

/// ISSUE acceptance: on a random CC-LP instance with n = 200 the active
/// strategy reaches the same final max_violation / lp_objective (within
/// 1e-6) as the full solver while performing measurably fewer constraint
/// visits, all reported through `Solution`.
#[test]
fn active_strategy_acceptance_n200() {
    let inst = CcLpInstance::random(200, 0.5, 0.9, 1.1, 17);

    // (1) sweep_every = 1 degenerates to the full solver, bitwise — the
    // active machinery's sweeps ARE the paper's passes.
    let short = SolveOpts { max_passes: 8, threads: 4, tile: 40, ..Default::default() };
    let full8 = dykstra_parallel::solve(&inst, &short);
    let act8 = dykstra_parallel::solve(
        &inst,
        &SolveOpts {
            strategy: Strategy::Active { sweep_every: 1, forget_after: 1 },
            ..short
        },
    );
    assert_eq!(full8.x, act8.x, "sweep_every=1 must be the full solver bitwise");
    assert_eq!(full8.metric_visits, act8.metric_visits);

    // (2) converged comparison: drive both to the same violation tol,
    // tightening until the acceptance tolerances hold (both strategies
    // converge geometrically to the same unique QP projection, so some
    // level terminates the ladder).
    let active = Strategy::Active { sweep_every: 5, forget_after: 2 };
    let mut level = 1e-7f64;
    loop {
        let base = SolveOpts {
            max_passes: 10_000,
            check_every: 10,
            tol_violation: level,
            tol_gap: 1e30, // violation-driven stop
            threads: 4,
            tile: 40,
            ..Default::default()
        };
        let full = dykstra_parallel::solve(&inst, &base);
        let act = dykstra_parallel::solve(&inst, &SolveOpts { strategy: active, ..base });
        assert!(full.passes < 10_000, "full solver failed to reach tol {level:.0e}");
        assert!(act.passes < 10_000, "active solver failed to reach tol {level:.0e}");
        // The work claim holds at every level: fewer total metric visits.
        assert!(
            act.metric_visits < full.metric_visits,
            "active visits {} !< full visits {}",
            act.metric_visits,
            full.metric_visits
        );
        let dv = (full.residuals.max_violation - act.residuals.max_violation).abs();
        let lp = full.residuals.lp_objective;
        let dlp = (lp - act.residuals.lp_objective).abs() / lp.abs().max(1.0);
        if dv <= 1e-6 && dlp <= 1e-6 {
            // Both counters are also visible at checkpoint granularity.
            assert!(act.residuals.metric_visits > 0);
            assert!(act.active_triplets < full.active_triplets);
            break;
        }
        level /= 10.0;
        assert!(level >= 1e-12, "ladder exhausted: dv={dv:.3e} dlp={dlp:.3e}");
    }
}

#[test]
fn eval_harness_smoke_end_to_end() {
    use metric_proj::eval::{self, EvalConfig, Scale, TilePolicy, TimingMode};
    let cfg = EvalConfig {
        scale: Scale::Smoke,
        passes: 1,
        tile: TilePolicy::PaperRatio,
        cores: vec![4],
        seed: 7,
        assignment: Default::default(),
        timing: TimingMode::Simulated,
    };
    let rows = eval::table1(&cfg, &[Dataset::CaGrQc], |_| {});
    assert_eq!(rows.len(), 2); // serial + 1 core count
    assert!(rows[1].speedup > 1.0, "simulated 4-core speedup {}", rows[1].speedup);
    let pts = eval::fig7(&cfg, Dataset::CaGrQc, 4, &[4, 16], |_, _, _| {});
    assert_eq!(pts.len(), 2);
}

#[test]
fn solver_handles_extreme_weights_and_signs() {
    // Failure-injection-flavored robustness: weight ratios of 1e4 and
    // all-negative / all-positive instances must not produce NaNs.
    for (p_neg, w_lo, w_hi) in [(0.0, 1.0, 1.0), (1.0, 1.0, 1.0), (0.5, 1e-2, 1e2)] {
        let inst = CcLpInstance::random(12, p_neg, w_lo, w_hi, 9);
        let sol = dykstra_parallel::solve(
            &inst,
            &SolveOpts { max_passes: 150, threads: 2, tile: 4, ..Default::default() },
        );
        for (_, _, v) in sol.x.iter_pairs() {
            assert!(v.is_finite(), "non-finite x (p_neg={p_neg})");
        }
        assert!(sol.residuals.max_violation.is_finite());
        assert!(sol.residuals.lp_objective >= -1e-9);
    }
}

#[test]
fn runtime_artifacts_compose_when_built() {
    // Exercised fully only after `make artifacts`; skips otherwise so
    // `cargo test` works from a clean checkout.
    if !std::path::Path::new("artifacts/project_b1024.hlo.txt").exists() {
        eprintln!("skipping runtime integration: run `make artifacts`");
        return;
    }
    let engine = metric_proj::runtime::engine::XlaEngine::load("artifacts").unwrap();
    let inst = CcLpInstance::random(10, 0.5, 0.8, 1.5, 3);
    let opts = SolveOpts { max_passes: 120, tile: 4, ..Default::default() };
    let xla = metric_proj::solver::dykstra_xla::solve(&inst, &opts, &engine).unwrap();
    let cpu = dykstra_parallel::solve(&inst, &opts);
    assert!(
        (xla.residuals.lp_objective - cpu.residuals.lp_objective).abs()
            < 1e-2 * cpu.residuals.lp_objective.max(1.0),
        "engines disagree: {} vs {}",
        xla.residuals.lp_objective,
        cpu.residuals.lp_objective
    );
}

#[test]
fn graph_io_roundtrip_through_instance() {
    let g = Dataset::CaGrQc.generate(50, 21);
    let dir = std::env::temp_dir().join("metric_proj_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ca-GrQc.txt");
    metric_proj::graph::io::write_edge_list(&g, &path).unwrap();
    // load_or_generate must prefer the file. Loading may relabel nodes
    // (ids are interned in file order), so compare graph invariants and
    // the *distribution* of instance entries, which are label-invariant.
    let loaded = Dataset::CaGrQc.load_or_generate(&dir, 999, 1);
    let lcc = largest_component(&g);
    assert_eq!(loaded.n(), lcc.n());
    assert_eq!(loaded.m(), lcc.m());
    let mut deg_a: Vec<usize> = (0..lcc.n()).map(|u| lcc.degree(u)).collect();
    let mut deg_b: Vec<usize> = (0..loaded.n()).map(|u| loaded.degree(u)).collect();
    deg_a.sort_unstable();
    deg_b.sort_unstable();
    assert_eq!(deg_a, deg_b);
    let a = build_cc_instance(&lcc, ConstructionParams::default(), 1);
    let b = build_cc_instance(&loaded, ConstructionParams::default(), 1);
    let negs = |inst: &CcLpInstance| inst.d.as_slice().iter().filter(|&&v| v == 1.0).count();
    assert_eq!(negs(&a), negs(&b));
    let wsum = |inst: &CcLpInstance| inst.w.as_slice().iter().sum::<f64>();
    assert!((wsum(&a) - wsum(&b)).abs() < 1e-9);
}
