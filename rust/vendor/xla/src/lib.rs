//! Offline compile-only stub of the `xla` crate surface used by
//! `metric_proj::runtime`.
//!
//! The real crate binds PJRT and the XLA compiler, which require native
//! libraries that cannot be fetched in the offline build environment.
//! This stub compiles the same API so the CPU solver, CLI, benches,
//! examples, and tests build and run unchanged; any path that would
//! actually need a compiled XLA executable fails gracefully at runtime
//! with a descriptive [`Error`] (callers already treat a missing XLA
//! backend as "artifacts unavailable" and skip or fall back to the CPU
//! engine).

use std::fmt;

/// Error type matching the real crate's role: `Display + std::error::Error`,
/// so it threads through `anyhow` context chains.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the real `xla` PJRT bindings, which are unavailable in this offline build \
         (vendor/xla is a compile-only stub)"
    ))
}

/// PJRT client. The stub reports a 1-device CPU platform so environment
/// introspection (`metric-proj info`, runtime smoke tests) works; only
/// compilation/execution is unavailable.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create the CPU client.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    /// Platform name, e.g. "cpu".
    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        1
    }

    /// Compile an HLO computation. Always fails in the stub.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_unavailable("compiling an HLO module"))
    }
}

/// Parsed HLO module. Never constructible in the stub (parsing fails),
/// which keeps every downstream execution path unreachable.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse HLO text from a file. The stub distinguishes a missing file
    /// (same error callers see from the real crate) from a present one.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        if std::path::Path::new(path).exists() {
            Err(stub_unavailable("parsing HLO text"))
        } else {
            Err(Error(format!("no such file: {path}")))
        }
    }
}

/// An XLA computation wrapping a parsed HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable. Unreachable in the stub (compile always fails).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; returns per-device output buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_unavailable("executing a compiled module"))
    }
}

/// A device buffer holding one output.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_unavailable("fetching a device buffer"))
    }
}

/// Element types a [`Literal`] can hold. The repo only moves `f32`.
pub trait NativeType: Copy {
    fn to_f32(self) -> f32;
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn to_f32(self) -> f32 {
        self
    }
    fn from_f32(v: f32) -> f32 {
        v
    }
}

/// Host-side literal: flat data plus dimensions.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal {
            data: values.iter().map(|&v| v.to_f32()).collect(),
            dims: vec![values.len() as i64],
        }
    }

    /// Reshape to new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "cannot reshape {} elements to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Dimensions of the literal.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Split a tuple literal into its elements. Tuple literals only come
    /// back from executions, which the stub cannot perform.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(stub_unavailable("untupling an execution result"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_cpu() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu");
        assert_eq!(c.device_count(), 1);
    }

    #[test]
    fn missing_file_errors_distinctly() {
        let e = HloModuleProto::from_text_file("/definitely/not/here.hlo.txt").unwrap_err();
        assert!(e.to_string().contains("no such file"));
    }

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }
}
