//! Minimal offline subset of the `anyhow` crate API.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides exactly the surface the repository uses: [`Error`] (a boxed
//! message chain), [`Result`], the [`Context`] extension trait for
//! `Result` and `Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Error sources are flattened to strings at conversion time, which keeps
//! the implementation tiny while preserving the `Display`/`Debug` chain
//! output ("Caused by: ...") that callers rely on for diagnostics.

use std::fmt;

/// `Result<T, anyhow::Error>`, with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A flattened error chain: `chain[0]` is the outermost context, each
/// following entry a cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The error chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost cause.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Mirrors anyhow's blanket conversion. Coherent because `Error` itself
// does not implement `std::error::Error` (exactly as in the real crate).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(|| ...)`.
pub trait Context<T, E> {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Attach a lazily-evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = Err::<(), _>(io_err()).context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file");
        assert_eq!(e.root_cause(), "missing");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("empty").unwrap_err().to_string(), "empty");
        assert_eq!(Some(3u32).context("empty").unwrap(), 3);
    }

    #[test]
    fn macros_work() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        let e = anyhow!("x = {}", 5);
        assert_eq!(e.to_string(), "x = 5");
        let s = String::from("plain");
        assert_eq!(anyhow!(s).to_string(), "plain");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "missing");
    }
}
