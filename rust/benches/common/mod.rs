//! Shared bench-harness helpers (criterion is unavailable offline; the
//! benches are `harness = false` binaries driven by `cargo bench`).

use metric_proj::eval::{EvalConfig, Scale, TimingMode};

/// Passes per timing run: the paper uses 20; benches default to 5 (the
/// speedup ratios are stable in the pass count) and honor
/// `METRIC_PROJ_BENCH_PASSES` for full-fidelity runs.
pub fn bench_passes() -> usize {
    std::env::var("METRIC_PROJ_BENCH_PASSES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(5)
}

/// Scale override via `METRIC_PROJ_BENCH_SCALE` (smoke|small|paper).
pub fn bench_scale() -> Scale {
    std::env::var("METRIC_PROJ_BENCH_SCALE")
        .ok()
        .and_then(|s| Scale::parse(&s))
        .unwrap_or(Scale::Small)
}

/// Default bench config: simulated timing unless the machine has real
/// parallelism AND `METRIC_PROJ_BENCH_TIMING=real` is set.
pub fn bench_config() -> EvalConfig {
    let mut cfg = EvalConfig::default();
    cfg.scale = bench_scale();
    cfg.passes = bench_passes();
    if let Ok(s) = std::env::var("METRIC_PROJ_BENCH_TIMING") {
        if let Some(t) = TimingMode::parse(&s) {
            cfg.timing = t;
        }
    }
    cfg
}

pub fn print_header(name: &str, cfg: &EvalConfig) {
    println!(
        "\n### bench {name}: scale={:?} passes={} tile={:?} timing={:?}",
        cfg.scale, cfg.passes, cfg.tile, cfg.timing
    );
}
