//! Regenerates the paper's **Figure 7**: speedup vs tile size on ca-GrQc
//! at 16 cores, tiles 5..50 step 5 (paper: rises to a peak near b=25,
//! then slowly decreases).
//!
//!     cargo bench --bench fig7_tiles

mod common;

use metric_proj::eval::fig7;
use metric_proj::graph::datasets::Dataset;

fn main() {
    let cfg = common::bench_config();
    common::print_header("fig7 (ca-GrQc, speedup vs tile size, 16 cores)", &cfg);
    let tiles: Vec<usize> = (1..=10).map(|i| i * 5).collect();
    let pts = fig7(&cfg, Dataset::CaGrQc, 16, &tiles, |b, t, s| {
        println!("tile={b:<3} time={t:>8.2}s speedup={s:.2}");
    });
    println!("\nspeedup curve:");
    for (b, _, s) in &pts {
        println!("b={b:>2} | {}", "#".repeat((s * 4.0).round() as usize));
    }
}
