//! Ablations over the design choices DESIGN.md calls out:
//!
//!   A1. Constraint ordering / tiling (single-thread wall time): serial
//!       lexicographic [37] vs wave schedule with b = 1 (Fig 2) vs tiled
//!       b = 40 (Fig 4) — isolates the cache effect of tiling.
//!   A2. Assignment policy (simulated p-core time): the paper's r mod p
//!       vs the rotated variant, tiled schedule, p in {8, 32}.
//!   A3. Projection engine: scalar CPU hot path vs the AOT-compiled
//!       Pallas kernel through PJRT (visits/second) — quantifies PJRT
//!       dispatch overhead at CPU batch sizes.
//!   A4. Constraint-visit strategy: the paper's full sweeps vs the
//!       project-and-forget active set (solver::active) — constraint
//!       visits per pass, total work, and solution quality at an equal
//!       pass budget.
//!
//!     cargo bench --bench ablations

mod common;

use metric_proj::eval::simulate::instrument;
use metric_proj::eval::{build_instance, regression, strategy_ablation, time_serial};
use metric_proj::graph::datasets::Dataset;
use metric_proj::solver::schedule::{Assignment, Schedule};
use metric_proj::solver::{dykstra_parallel, dykstra_xla, SolveOpts, Strategy};
use metric_proj::util::parallel::available_cores;
use metric_proj::util::timer::time;

fn main() {
    let cfg = common::bench_config();
    common::print_header("ablations", &cfg);
    let inst = build_instance(Dataset::CaGrQc, &cfg);
    println!("instance: ca-GrQc analogue n={}", inst.n);

    // --- A1: ordering / tiling, single thread ---------------------------
    println!("\n[A1] constraint order (single-thread wall time, {} passes)", cfg.passes);
    let t_lex = time_serial(&inst, cfg.passes);
    println!("  serial lexicographic [37] : {t_lex:>7.2}s (baseline)");
    for b in [1usize, 5, 40] {
        let opts = SolveOpts {
            max_passes: cfg.passes,
            threads: 1,
            tile: b,
            check_every: 0,
            track_pass_times: true,
            ..Default::default()
        };
        let sol = dykstra_parallel::solve(&inst, &opts);
        let t: f64 = sol.pass_times.iter().sum();
        println!("  wave schedule b={b:<3}        : {t:>7.2}s ({:+.1}% vs lex)", (t / t_lex - 1.0) * 100.0);
    }

    // --- A2: assignment policy -------------------------------------------
    // The paper's r mod p systematically hands worker 0 the largest tile
    // of every wave; this bites when waves hold only a few tiles per
    // worker. Report both the deterministic load imbalance and the
    // simulated pass time, at a tile size giving ~3 tiles/worker (the
    // regime of the paper's Table I runs).
    println!("\n[A2] tile-to-worker assignment:");
    for p in [8usize, 16] {
        let b_a2 = (inst.n / (3 * p)).max(2);
        let schedule = Schedule::new(inst.n, b_a2);
        let ins = instrument(&inst, &schedule, cfg.passes);
        let imb = |a: Assignment| {
            let loads: Vec<f64> =
                schedule.worker_loads(p, a).iter().map(|&x| x as f64).collect();
            metric_proj::util::stats::load_imbalance(&loads)
        };
        let rr = ins.simulate(p, Assignment::RoundRobin);
        let rot = ins.simulate(p, Assignment::Rotated);
        println!(
            "  p={p:<3} b={b_a2:<3} round-robin (paper): {rr:>7.3}s (imbalance {:>5.1}%) | rotated: {rot:>7.3}s (imbalance {:>5.1}%, {:+.1}% time)",
            imb(Assignment::RoundRobin) * 100.0,
            imb(Assignment::Rotated) * 100.0,
            (rot / rr - 1.0) * 100.0
        );
    }
    println!(
        "  -> finding: rotation fixes the *cumulative* imbalance (worker 0 no\n     longer owns every wave's biggest tile) but pass time is set by the\n     per-wave critical path, which barriers make invariant to who owns\n     which tile. The paper's Fig-3 concern matters for fairness/energy,\n     not wall-clock, as long as waves are barrier-separated."
    );

    // --- A3: projection engine -------------------------------------------
    println!("\n[A3] projection engine (n=50, {} passes):", cfg.passes);
    let small = build_instance_small();
    let visits = small.n_metric_constraints() as f64 * cfg.passes as f64;
    let opts = SolveOpts { max_passes: cfg.passes, threads: 1, tile: 16, ..Default::default() };
    let (_, t_cpu) = time(|| dykstra_parallel::solve(&small, &opts));
    println!("  CPU scalar engine : {t_cpu:>7.2}s ({:.2e} visits/s)", visits / t_cpu);
    match metric_proj::runtime::engine::XlaEngine::load("artifacts") {
        Ok(engine) => {
            let (res, t_xla) = time(|| dykstra_xla::solve(&small, &opts, &engine));
            res.expect("xla solve");
            println!("  XLA/PJRT engine   : {t_xla:>7.2}s ({:.2e} visits/s)", visits / t_xla);
            println!(
                "  -> PJRT dispatch overhead dominates at CPU batch sizes ({:.0}x slower);\n     the kernel exists for TPU offload + layer-composition proof.",
                t_xla / t_cpu
            );
        }
        Err(e) => println!("  XLA engine unavailable ({e}); run `make artifacts`"),
    }

    // --- A4: constraint-visit strategy -----------------------------------
    // Equal pass budget, long enough that the dual support has sparsified;
    // the interesting numbers are visits/pass and total visits vs quality.
    let a4_passes = cfg.passes.max(24);
    println!(
        "\n[A4] constraint visits: full sweeps vs project-and-forget ({a4_passes} passes)"
    );
    let base = SolveOpts {
        max_passes: a4_passes,
        threads: available_cores(),
        tile: 16,
        check_every: 0,
        ..Default::default()
    };
    // Each row solved (and timed) separately so the regression rows get
    // an honest per-strategy wall time next to the work counters.
    let mut rows: Vec<(metric_proj::eval::StrategyRow, f64)> = Vec::new();
    for (label, strategy) in [
        ("full", Strategy::Full),
        ("active s=4 k=2", Strategy::Active { sweep_every: 4, forget_after: 2 }),
        ("active s=8 k=3", Strategy::Active { sweep_every: 8, forget_after: 3 }),
        ("active s=16 k=3", Strategy::Active { sweep_every: 16, forget_after: 3 }),
    ] {
        let (mut r, secs) = time(|| strategy_ablation(&small, &base, &[(label, strategy)]));
        rows.push((r.remove(0), secs));
    }
    // One out-of-core row: the same active solve streaming X and W from
    // a disk tile store under a quarter-of-packed budget — identical
    // numerics (disk == mem bitwise), honest resident-memory column.
    {
        let dir = std::env::temp_dir()
            .join(format!("metric_proj_ablations_a4_{}", std::process::id()));
        let m = small.n * small.n.saturating_sub(1) / 2;
        let store = metric_proj::matrix::store::StoreCfg::disk(&dir, (m * 8 / 4).max(1 << 12));
        let (res, secs) = time(|| {
            metric_proj::eval::strategy_ablation_stored(
                &small,
                &base,
                &store,
                &[("active s=8 +disk", Strategy::Active { sweep_every: 8, forget_after: 3 })],
            )
        });
        match res {
            Ok(mut disk_rows) => rows.push((disk_rows.remove(0), secs)),
            Err(e) => println!("  (disk row skipped: {e})"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
    let full_visits = rows[0].0.metric_visits.max(1) as f64;
    for (r, _) in &rows {
        let hit = match r.screen_hit_rate() {
            Some(h) => format!("{:>5.1}%", 100.0 * h),
            None => "    -".to_string(),
        };
        println!(
            "  {:<16} visits/pass={:>10.3e} total={:>10.3e} ({:>5.1}% of full) active={:<8} screen-hit={hit} viol={:.2e} lp={:.4} resident~{:.1}MiB",
            r.label,
            r.visits_per_pass,
            r.metric_visits as f64,
            100.0 * r.metric_visits as f64 / full_visits,
            r.active_triplets,
            r.max_violation,
            r.lp_objective,
            r.resident_mb_est
        );
    }
    println!(
        "  -> finding: once duals sparsify, cheap passes touch a small fraction\n     of the 3*C(n,3) rows; sweep cadence trades staleness (violation\n     discovered late) against the dominant sweep cost. The screen hit\n     rate shows why the screened sweep backend wins: almost every sweep\n     visit is a provable no-op (cargo bench --bench sweep quantifies it)."
    );

    // Machine-normalized regression rows (same contract as the sweep
    // bench): visits per calibration unit per (n, strategy, store) cell,
    // merged into `bench/baseline.json` under `--commit-baseline`.
    let calib_ns = regression::calibrate();
    println!("\ncalibration: {calib_ns:.3} ns/op (throughput normalized by this)");
    let reg_rows: Vec<regression::BaselineRow> = rows
        .iter()
        .map(|(r, secs)| regression::BaselineRow {
            bench: "ablations".to_string(),
            n: small.n as u64,
            cell: r.label.to_string(),
            store: if r.label.contains("+disk") { "disk" } else { "mem" }.to_string(),
            visits_per_unit: regression::normalize(
                r.metric_visits as f64 / secs.max(1e-9),
                calib_ns,
            ),
            hit_rate: r.screen_hit_rate().unwrap_or(0.0),
            store_loads: 0,
            peak_resident_bytes: (r.resident_mb_est * (1u64 << 20) as f64) as u64,
            entry_loads: 0,
            blocks_skipped: 0,
            shard_bytes: 0,
            barrier_wait_us: 0,
        })
        .collect();
    let rows_path = std::env::var("METRIC_PROJ_BENCH_ROWS")
        .unwrap_or_else(|_| "../BENCH_ablations.rows.json".to_string());
    let baseline_path = std::env::var("METRIC_PROJ_BASELINE")
        .unwrap_or_else(|_| "../bench/baseline.json".to_string());
    let commit = std::env::args().any(|a| a == "--commit-baseline");
    if let Err(e) = regression::emit_rows(
        reg_rows,
        std::path::Path::new(&rows_path),
        commit,
        std::path::Path::new(&baseline_path),
    ) {
        eprintln!("warning: could not emit regression rows: {e}");
    }
}

fn build_instance_small() -> metric_proj::instance::CcLpInstance {
    let g = Dataset::CaGrQc.generate(50, 42);
    metric_proj::instance::construction::build_cc_instance(
        &g,
        metric_proj::instance::construction::ConstructionParams::default(),
        1,
    )
}
