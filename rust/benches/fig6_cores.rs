//! Regenerates the paper's **Figure 6**: speedup vs core count on
//! ca-HepPh with tile size 40 (paper: 1 core, then 8..40 in steps of 4 —
//! performance climbs sharply then levels off).
//!
//!     cargo bench --bench fig6_cores

mod common;

use metric_proj::eval::fig6;
use metric_proj::graph::datasets::Dataset;

fn main() {
    let cfg = common::bench_config();
    common::print_header("fig6 (ca-HepPh, speedup vs cores)", &cfg);
    let cores: Vec<usize> = (8..=40).step_by(4).collect();
    let pts = fig6(&cfg, Dataset::CaHepPh, &cores, |c, t, s| {
        println!("cores={c:<3} time={t:>8.2}s speedup={s:.2}");
    });
    // ascii curve
    println!("\nspeedup curve:");
    for (c, _, s) in &pts {
        println!("{c:>3} | {}", "#".repeat((s * 4.0).round() as usize));
    }
}
