//! Regenerates the paper's **Table I**: time for a fixed number of
//! Dykstra iterations on all five graphs at 1/8/16/32/64 cores, tile 40.
//!
//!     cargo bench --bench table1
//!     METRIC_PROJ_BENCH_PASSES=20 METRIC_PROJ_BENCH_SCALE=small cargo bench --bench table1

mod common;

use metric_proj::eval::{render_table1, table1};
use metric_proj::graph::datasets::Dataset;

fn main() {
    let cfg = common::bench_config();
    common::print_header("table1", &cfg);
    println!(
        "paper reference shapes: 8 cores 4.2-5.1x | 16 cores 5.3-6.7x | 32 cores 7.3-8x | 64 cores 11.5x"
    );
    let rows = table1(&cfg, &Dataset::ALL, |r| {
        println!(
            "{:<11} n={:<5} cores={:<3} time={:>8.2}s speedup={:.2}",
            r.dataset, r.n, r.cores, r.time_s, r.speedup
        );
    });
    println!("\n{}", render_table1(&rows));
}
