//! Sweep backend throughput: the screen-then-project engine vs the
//! scalar callback sweep (EXPERIMENTS.md §Perf), plus the out-of-core
//! tile store.
//!
//! For each problem size the harness first runs a short active-set
//! nearness solve to reach the steady state where the paper's sparsity
//! argument bites (few violated rows, few nonzero duals), then times
//! repeated discovery sweeps per [`SweepBackend`] from identical states.
//! The interesting number is triplet-visits/second: every backend
//! examines all `C(n,3)` triplets per sweep, so throughput differences
//! are pure per-triplet overhead. A final `screened+disk` row repeats
//! the screened sweep with `X` streamed from a [`DiskStore`] under a
//! cache budget of one quarter of the packed matrix — the out-of-core
//! throughput tax, measured against the same steady state. A
//! `screened+shard` row repeats it once more against a [`ShardStore`]
//! with two in-process workers behind the coordinator↔worker socket
//! protocol — the multi-process transport tax, with bytes-over-socket
//! and barrier-wait columns feeding the CI gate (traffic is
//! schedule-deterministic and gated; barrier wait is wall clock and
//! informational only).
//!
//! Every row also reports a **peak resident-set estimate** for the `X`
//! path (packed `x` + `winv` for the in-memory backends; the measured
//! peak block caches — the `X` plane plus the streamed-`W` plane — for
//! the disk store, which keeps no packed array resident at all), so the
//! bench doubles as the memory column of the out-of-core story.
//!
//!     cargo bench --bench sweep
//!
//! Per size the harness also times the **proximal family** end to end
//! (`prox-mm` / `prox-sd` rows, ARCHITECTURE.md §6): their CG/gradient
//! sweeps bill the same per-triplet visit unit, so the throughput
//! column stays comparable across families.
//!
//! Environment knobs: `METRIC_PROJ_SWEEP_NS` (comma-separated sizes,
//! default `120,200,300`), `METRIC_PROJ_SWEEP_REPS` (timed sweeps per
//! backend, default 5), `METRIC_PROJ_SWEEP_WARMUP` (steady-state solve
//! passes, default 30), `METRIC_PROJ_SWEEP_THREADS` (default 1 — the
//! cleanest per-core throughput comparison), `METRIC_PROJ_SWEEP_PROX_MAX_N`
//! (skip the proximal rows above this size, default 200),
//! `METRIC_PROJ_BENCH_OUT` (output path, default `../BENCH_sweep.json`
//! = the repo root when run via `cargo bench`).
//!
//! Emits machine-readable `BENCH_sweep.json` for the perf trajectory:
//! one record per (n, backend) with triplet-visits/sec, the screen hit
//! rate, and the resident-set estimate in MiB.
//!
//! Also emits machine-**normalized** regression rows (visits per
//! calibration unit — see [`metric_proj::eval::regression`]) to
//! `METRIC_PROJ_BENCH_ROWS` (default `../BENCH_sweep.rows.json`) for the
//! CI gate (`metric-proj bench-gate`). Pass `--commit-baseline`
//! (`cargo bench --bench sweep -- --commit-baseline`) to merge the rows
//! into the committed baseline at `METRIC_PROJ_BASELINE` (default
//! `../bench/baseline.json`).

use metric_proj::eval::regression;
use metric_proj::instance::metric_nearness::MetricNearnessInstance;
use metric_proj::matrix::store::{DiskStore, MemStore, ShardStore, StoreCfg};
use metric_proj::runtime::engine::XlaEngine;
use metric_proj::runtime::DEFAULT_ARTIFACTS_DIR;
use metric_proj::solver::active::active_pass;
use metric_proj::solver::active::set::ActiveSet;
use metric_proj::solver::active::sweep::{discovery_sweep, SweepReport};
use metric_proj::solver::nearness::{self, NearnessOpts};
use metric_proj::solver::schedule::{Assignment, Schedule};
use metric_proj::solver::{Algorithm, Strategy, SweepBackend};
use std::fmt::Write as _;
use std::time::Instant;

const BACKENDS: [SweepBackend; 3] =
    [SweepBackend::Scalar, SweepBackend::Screened, SweepBackend::Engine];

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_ns() -> Vec<usize> {
    match std::env::var("METRIC_PROJ_SWEEP_NS") {
        Ok(s) => s.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
        Err(_) => vec![120, 200, 300],
    }
}

struct Record {
    n: usize,
    backend: &'static str,
    /// `X` storage backend of the row (`mem` / `disk`), for the
    /// regression-row key.
    store: &'static str,
    sweeps: usize,
    seconds: f64,
    visits_per_sec: f64,
    hit_rate: f64,
    speedup_vs_scalar: f64,
    resident_mb: f64,
    /// Tile-store block loads over the timed sweeps (0 for mem rows).
    store_loads: u64,
    /// Entries gathered through entry-granular leases over the timed
    /// region (only the `cheap-pass` row takes that path).
    entry_loads: u64,
    /// Whole-tile footprint blocks those leases skipped.
    blocks_skipped: u64,
    /// Bytes over the coordinator↔worker sockets (shard rows only).
    shard_bytes: u64,
    /// Coordinator barrier-wait time in µs (shard rows only).
    barrier_wait_us: u64,
}

fn mib(bytes: f64) -> f64 {
    bytes / (1u64 << 20) as f64
}

fn main() {
    let ns = env_ns();
    let reps = env_usize("METRIC_PROJ_SWEEP_REPS", 5).max(1);
    let warmup = env_usize("METRIC_PROJ_SWEEP_WARMUP", 30);
    let threads = env_usize("METRIC_PROJ_SWEEP_THREADS", 1).max(1);
    let prox_max_n = env_usize("METRIC_PROJ_SWEEP_PROX_MAX_N", 200);
    let out_path = std::env::var("METRIC_PROJ_BENCH_OUT")
        .unwrap_or_else(|_| "../BENCH_sweep.json".to_string());
    println!(
        "\n### bench sweep: ns={ns:?} reps={reps} warmup={warmup} threads={threads}"
    );

    let mut records: Vec<Record> = Vec::new();
    for &n in &ns {
        let tile = (n / 12).clamp(4, 40);
        let schedule = Schedule::new(n, tile);
        let triplets = schedule.total_triplets();
        let inst = MetricNearnessInstance::random(n, 2.0, 42);

        // Steady state: a short active-set solve sparsifies the dual
        // support, the regime the screen is built for.
        let steady = nearness::solve(
            &inst,
            &NearnessOpts {
                max_passes: warmup,
                check_every: 0,
                threads,
                tile,
                strategy: Strategy::Active { sweep_every: 4, forget_after: 2 },
                ..Default::default()
            },
        );
        let x_steady: Vec<f64> = steady.x.as_slice().to_vec();
        let winv: Vec<f64> = inst.w.as_slice().iter().map(|&v| 1.0 / v).collect();
        let col_starts = inst.d.col_starts().to_vec();
        // The in-memory X path: packed x plus packed 1/w.
        let mem_resident_mb = mib((2 * x_steady.len() * 8) as f64);

        println!(
            "\n  n={n} tile={tile}: C(n,3)={triplets} triplets/sweep, \
             steady-state violation {:.2e}",
            steady.max_violation
        );
        let mut scalar_vps = None;
        for backend in BACKENDS {
            // Same resolution as the solver drivers: the engine backend
            // measures the real PJRT path when artifacts are present and
            // the (bitwise-equal) screened fallback otherwise.
            let engine = match backend {
                SweepBackend::Engine => XlaEngine::load(DEFAULT_ARTIFACTS_DIR).ok(),
                _ => None,
            };
            if backend == SweepBackend::Engine && engine.is_none() {
                println!("    engine   (no PJRT artifacts — measuring the screened fallback)");
            }
            let mut x = x_steady.clone();
            let set = ActiveSet::new(&schedule);
            let sweep_once = |x: &mut Vec<f64>, set: &ActiveSet| -> SweepReport {
                let store = MemStore::new(x.as_mut_slice(), &col_starts, &winv);
                discovery_sweep(
                    &store,
                    &schedule,
                    set,
                    threads,
                    Assignment::RoundRobin,
                    backend,
                    engine.as_ref(),
                )
            };
            // Untimed seed sweep: attaches the steady-state duals to the
            // set so the timed sweeps carry realistic merge-scan work.
            sweep_once(&mut x, &set);
            let t0 = Instant::now();
            let mut last = None;
            for _ in 0..reps {
                last = Some(sweep_once(&mut x, &set));
            }
            let dt = t0.elapsed().as_secs_f64().max(1e-9);
            let report = last.expect("reps >= 1");
            let vps = (reps as u64 * triplets) as f64 / dt;
            let speedup = match scalar_vps {
                None => {
                    scalar_vps = Some(vps);
                    1.0
                }
                Some(s) => vps / s,
            };
            println!(
                "    {:<13} {:>9.3e} triplet-visits/s ({:>5.2}x scalar), \
                 hit rate {:>6.3}%, {:.3}s for {} sweeps, ~{:.1} MiB resident X",
                backend.name(),
                vps,
                speedup,
                100.0 * report.hit_rate(),
                dt,
                reps,
                mem_resident_mb
            );
            records.push(Record {
                n,
                backend: backend.name(),
                store: "mem",
                sweeps: reps,
                seconds: dt,
                visits_per_sec: vps,
                hit_rate: report.hit_rate(),
                speedup_vs_scalar: speedup,
                resident_mb: mem_resident_mb,
                store_loads: 0,
                entry_loads: 0,
                blocks_skipped: 0,
                shard_bytes: 0,
                barrier_wait_us: 0,
            });
        }

        // Out-of-core row: the screened sweep with X streamed from a
        // disk tile store under a quarter-of-packed-X cache budget.
        {
            let path = std::env::temp_dir().join(format!(
                "metric_proj_bench_sweep_{n}_{}.tiles",
                std::process::id()
            ));
            let budget = (x_steady.len() * 8 / 4).max(1 << 12);
            let store = DiskStore::create(
                &path,
                n,
                tile,
                budget,
                winv.clone(),
                &mut |c, r| x_steady[col_starts[c] + (r - c - 1)],
            )
            .expect("create bench tile store");
            let mut set = ActiveSet::new(&schedule);
            let sweep_disk = |set: &ActiveSet| -> SweepReport {
                discovery_sweep(
                    &store,
                    &schedule,
                    set,
                    threads,
                    Assignment::RoundRobin,
                    SweepBackend::Screened,
                    None,
                )
            };
            sweep_disk(&set);
            let t0 = Instant::now();
            let mut last = None;
            for _ in 0..reps {
                last = Some(sweep_disk(&set));
            }
            let dt = t0.elapsed().as_secs_f64().max(1e-9);
            let report = last.expect("reps >= 1");
            let vps = (reps as u64 * triplets) as f64 / dt;
            let speedup = scalar_vps.map_or(1.0, |s| vps / s);
            let stats = store.stats();
            // Measured peak caches only: since PR 5 the store streams
            // winv from its W plane instead of keeping it resident, so
            // the honest resident figure is the two planes' peak.
            let resident_mb = mib(stats.peak_resident_bytes as f64);
            println!(
                "    {:<13} {:>9.3e} triplet-visits/s ({:>5.2}x scalar), \
                 hit rate {:>6.3}%, {:.3}s for {} sweeps, ~{:.1} MiB resident X \
                 ({} loads + {} W-plane, {} evictions)",
                "screened+disk",
                vps,
                speedup,
                100.0 * report.hit_rate(),
                dt,
                reps,
                resident_mb,
                stats.loads,
                stats.w_loads,
                stats.evictions
            );
            records.push(Record {
                n,
                backend: "screened",
                store: "disk",
                sweeps: reps,
                seconds: dt,
                visits_per_sec: vps,
                hit_rate: report.hit_rate(),
                speedup_vs_scalar: speedup,
                resident_mb,
                store_loads: stats.loads,
                entry_loads: 0,
                blocks_skipped: 0,
                shard_bytes: 0,
                barrier_wait_us: 0,
            });

            // Cheap-pass row: the timed sweeps above left `set` holding
            // the surviving duals, so this times the entry-granular
            // active passes that dominate steady-state disk solves. The
            // counter deltas show the lease touching strictly less than
            // the whole-tile footprint.
            {
                let before = store.stats();
                let t0 = Instant::now();
                let mut visits = 0u64;
                for _ in 0..reps {
                    visits +=
                        active_pass(&store, &schedule, &set, threads, Assignment::RoundRobin);
                }
                let dt = t0.elapsed().as_secs_f64().max(1e-9);
                let after = store.stats();
                let entry_loads = after.entry_loads - before.entry_loads;
                let blocks_skipped = after.blocks_skipped - before.blocks_skipped;
                let loads = after.loads - before.loads;
                let vps = visits as f64 / dt;
                println!(
                    "    {:<13} {:>9.3e} triplet-visits/s, {:.3}s for {} passes \
                     ({} active triplets): {} entries gathered, {} block loads, \
                     {} footprint blocks skipped",
                    "cheap-pass",
                    vps,
                    dt,
                    reps,
                    set.len(),
                    entry_loads,
                    loads,
                    blocks_skipped
                );
                records.push(Record {
                    n,
                    backend: "cheap-pass",
                    store: "disk",
                    sweeps: reps,
                    seconds: dt,
                    visits_per_sec: vps,
                    hit_rate: 0.0,
                    speedup_vs_scalar: 0.0,
                    resident_mb: mib(store.stats().peak_resident_bytes as f64),
                    store_loads: loads,
                    entry_loads,
                    blocks_skipped,
                    shard_bytes: 0,
                    barrier_wait_us: 0,
                });
            }

            let store_path = store.path().to_path_buf();
            drop(store);
            let _ = std::fs::remove_file(store_path);
        }

        // Sharded row: the same screened sweep leased over the
        // coordinator↔worker socket protocol, two in-process workers
        // (`worker_exe: None` — the protocol and framing are identical
        // to the multi-process path, without fork cost polluting a
        // throughput bench). Socket traffic is schedule-deterministic
        // and feeds the gate's `shard_bytes` column; the per-rep
        // `health()` barrier accrues the (ungated) `barrier_wait_us`
        // column, exactly as the solver drivers poll per pass.
        {
            let dir = std::env::temp_dir().join(format!(
                "metric_proj_bench_shard_{n}_{}",
                std::process::id()
            ));
            let _ = std::fs::remove_dir_all(&dir);
            std::fs::create_dir_all(&dir).expect("create bench shard dir");
            let cfg = StoreCfg::shard(&dir, 2);
            let store = ShardStore::create_with(&cfg, n, winv.clone(), &mut |c, r| {
                x_steady[col_starts[c] + (r - c - 1)]
            })
            .expect("create bench shard store");
            let set = ActiveSet::new(&schedule);
            let sweep_shard = |set: &ActiveSet| -> SweepReport {
                discovery_sweep(
                    &store,
                    &schedule,
                    set,
                    threads,
                    Assignment::RoundRobin,
                    SweepBackend::Screened,
                    None,
                )
            };
            sweep_shard(&set);
            let before = store.stats();
            let t0 = Instant::now();
            let mut last = None;
            for _ in 0..reps {
                last = Some(sweep_shard(&set));
                store.health().expect("shard workers healthy");
            }
            let dt = t0.elapsed().as_secs_f64().max(1e-9);
            let report = last.expect("reps >= 1");
            let vps = (reps as u64 * triplets) as f64 / dt;
            let speedup = scalar_vps.map_or(1.0, |s| vps / s);
            let after = store.stats();
            let shard_bytes = (after.shard_bytes_in - before.shard_bytes_in)
                + (after.shard_bytes_out - before.shard_bytes_out);
            let barrier_wait_us = after.barrier_wait_us - before.barrier_wait_us;
            println!(
                "    {:<13} {:>9.3e} triplet-visits/s ({:>5.2}x scalar), \
                 hit rate {:>6.3}%, {:.3}s for {} sweeps, ~{:.1} MiB resident X \
                 ({} requests, {:.1} MiB over sockets, {:.1} ms barrier wait)",
                "screened+shard",
                vps,
                speedup,
                100.0 * report.hit_rate(),
                dt,
                reps,
                mem_resident_mb,
                after.shard_requests - before.shard_requests,
                mib(shard_bytes as f64),
                barrier_wait_us as f64 / 1e3
            );
            records.push(Record {
                n,
                backend: "screened",
                store: "shard",
                sweeps: reps,
                seconds: dt,
                visits_per_sec: vps,
                hit_rate: report.hit_rate(),
                speedup_vs_scalar: speedup,
                // The workers collectively keep the packed x and winv
                // planes resident, split across their slices — the same
                // footprint as the in-memory row, just partitioned.
                resident_mb: mem_resident_mb,
                store_loads: 0,
                entry_loads: 0,
                blocks_skipped: 0,
                shard_bytes,
                barrier_wait_us,
            });
            drop(store);
            let _ = std::fs::remove_dir_all(&dir);
        }

        // Proximal-family rows (ARCHITECTURE.md §6): end-to-end solves
        // of the second algorithm family on the same instance, timed to
        // a loose 1e-5 violation. CG and gradient sweeps bill every
        // triplet per matvec, so triplet-visits/s stays the comparable
        // unit; hit rate and speedup-vs-scalar do not apply (0, like
        // the cheap-pass row). Skipped above METRIC_PROJ_SWEEP_PROX_MAX_N:
        // MM runs thousands of O(n³) matvec sweeps per solve at n = 300.
        if n <= prox_max_n {
            for (algorithm, label, vectors) in
                [(Algorithm::ProxMm, "prox-mm", 11usize), (Algorithm::ProxSd, "prox-sd", 7)]
            {
                let t0 = Instant::now();
                let sol = nearness::solve(
                    &inst,
                    &NearnessOpts {
                        tol_violation: 1e-5,
                        threads,
                        tile,
                        algorithm,
                        ..Default::default()
                    },
                );
                let dt = t0.elapsed().as_secs_f64().max(1e-9);
                let vps = sol.metric_visits as f64 / dt;
                // Packed work-vector count of the driver (x/anchor/rhs/
                // CG scratch... plus d and winv), the resident X path.
                let resident_mb = mib((vectors * x_steady.len() * 8) as f64);
                println!(
                    "    {:<13} {:>9.3e} triplet-visits/s, {:.3}s to 1e-5 violation \
                     ({} outer iterations), ~{:.1} MiB resident X",
                    label, vps, dt, sol.passes, resident_mb
                );
                records.push(Record {
                    n,
                    backend: label,
                    store: "mem",
                    sweeps: sol.passes,
                    seconds: dt,
                    visits_per_sec: vps,
                    hit_rate: 0.0,
                    speedup_vs_scalar: 0.0,
                    resident_mb,
                    store_loads: 0,
                    entry_loads: 0,
                    blocks_skipped: 0,
                    shard_bytes: 0,
                    barrier_wait_us: 0,
                });
            }
        }
    }

    let mut json = String::from("{\n  \"bench\": \"sweep\",\n");
    json.push_str("  \"unit\": \"triplet_visits_per_sec\",\n");
    let _ = writeln!(json, "  \"threads\": {threads},");
    json.push_str("  \"results\": [\n");
    for (idx, r) in records.iter().enumerate() {
        let label = if r.store == "mem" {
            r.backend.to_string()
        } else {
            format!("{}+{}", r.backend, r.store)
        };
        let _ = write!(
            json,
            "    {{\"n\": {}, \"backend\": \"{}\", \"sweeps\": {}, \"seconds\": {:.6}, \
             \"triplet_visits_per_sec\": {:.1}, \"screen_hit_rate\": {:.6}, \
             \"speedup_vs_scalar\": {:.4}, \"resident_mb\": {:.3}}}",
            r.n, label, r.sweeps, r.seconds, r.visits_per_sec, r.hit_rate,
            r.speedup_vs_scalar, r.resident_mb
        );
        json.push_str(if idx + 1 < records.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nwarning: could not write {out_path}: {e}"),
    }

    // Machine-normalized regression rows for the CI gate, committed into
    // the baseline under `--commit-baseline`.
    let calib_ns = regression::calibrate();
    println!("calibration: {calib_ns:.3} ns/op (throughput normalized by this)");
    let rows: Vec<regression::BaselineRow> = records
        .iter()
        .map(|r| regression::BaselineRow {
            bench: "sweep".to_string(),
            n: r.n as u64,
            cell: r.backend.to_string(),
            store: r.store.to_string(),
            visits_per_unit: regression::normalize(r.visits_per_sec, calib_ns),
            hit_rate: r.hit_rate,
            store_loads: r.store_loads,
            peak_resident_bytes: (r.resident_mb * (1u64 << 20) as f64) as u64,
            entry_loads: r.entry_loads,
            blocks_skipped: r.blocks_skipped,
            shard_bytes: r.shard_bytes,
            barrier_wait_us: r.barrier_wait_us,
        })
        .collect();
    let rows_path = std::env::var("METRIC_PROJ_BENCH_ROWS")
        .unwrap_or_else(|_| "../BENCH_sweep.rows.json".to_string());
    let baseline_path = std::env::var("METRIC_PROJ_BASELINE")
        .unwrap_or_else(|_| "../bench/baseline.json".to_string());
    let commit = std::env::args().any(|a| a == "--commit-baseline");
    if let Err(e) = regression::emit_rows(
        rows,
        std::path::Path::new(&rows_path),
        commit,
        std::path::Path::new(&baseline_path),
    ) {
        eprintln!("warning: could not emit regression rows: {e}");
    }
}
