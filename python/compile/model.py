"""Layer-2 JAX model: the batched compute graph the Rust coordinator
executes through PJRT.

Three jittable entry points, all lowered to HLO text by `aot.py`:

* `triplet_sweep`   — one conflict-free wave of metric-constraint visits,
                      delegating the per-lane math to the L1 Pallas kernel.
* `pair_sweep`      — the per-pair constraint block of the CC-LP (3):
                      x - f <= d, -x - f <= -d, and the box x <= 1,
                      element-wise parallel over pairs.
* `objective_terms` — partial sums for the QP primal/dual and LP objective
                      over a batch of pairs (the reduction the coordinator
                      uses for termination checks).

Python never runs at solve time: these functions exist to be lowered once
(`make artifacts`) and executed from rust/src/runtime/.
"""

import jax
import jax.numpy as jnp

from .kernels.metric_project import project_triplets


@jax.jit
def triplet_sweep(x3, winv3, y3):
    """One wave of triplet projections (see kernels.metric_project)."""
    return project_triplets(x3, winv3, y3, block=min(1024, x3.shape[0]))


@jax.jit
def pair_sweep(x, f, winv, d, y_up, y_lo, y_box):
    """Dykstra visits to the pair constraints of one batch of pairs.

    Mirrors rust/src/solver/projection.rs::visit_pair_{upper,lower} and
    visit_box_upper, vectorized over the batch. Returns updated
    (x, f, y_up, y_lo, y_box).
    """
    # upper: x - f <= d
    delta = x - f - d + 2.0 * y_up * winv
    theta = jnp.maximum(delta, 0.0) / (2.0 * winv)
    c = y_up - theta
    x = x + c * winv
    f = f - c * winv
    y_up = theta
    # lower: -x - f <= -d
    delta = d - x - f + 2.0 * y_lo * winv
    theta = jnp.maximum(delta, 0.0) / (2.0 * winv)
    c = y_lo - theta
    x = x - c * winv
    f = f - c * winv
    y_lo = theta
    # box: x <= 1
    delta = x + y_box * winv - 1.0
    theta = jnp.maximum(delta, 0.0) / winv
    c = y_box - theta
    x = x + c * winv
    y_box = theta
    return x, f, y_up, y_lo, y_box


@jax.jit
def objective_terms(x, f, w, d, y_up, y_lo, y_box):
    """Partial reductions for termination metrics over a batch of pairs.

    Returns a (4,) vector: [c'x, x'Wx, b'yhat, lp_objective] contributions
    (summed over the batch; the coordinator accumulates across batches and
    assembles primal/dual/gap exactly as solver/termination.rs does).
    """
    cx = jnp.sum(w * f)
    xwx = jnp.sum(w * (x * x + f * f))
    b_yhat = jnp.sum(d * (y_up - y_lo) + y_box)
    lp = jnp.sum(w * jnp.abs(x - d))
    return jnp.stack([cx, xwx, b_yhat, lp])
