"""AOT lowering: JAX (L2+L1) -> HLO *text* artifacts for the Rust runtime.

HLO text — not `.serialize()` — is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` crate binds) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts

Emits, for batch sizes B in PROJECT_BATCHES:
  project_b{B}.hlo.txt    triplet_sweep    ((B,3) x3, winv3, y3) -> (x3', y3')
  pair_b{B}.hlo.txt       pair_sweep       7 x (B,) -> 5 x (B,)
  objective_b{B}.hlo.txt  objective_terms  7 x (B,) -> (4,)
plus a manifest.txt recording shapes and dtypes.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

PROJECT_BATCHES = (1024, 4096, 16384)
PAIR_BATCHES = (4096,)
OBJECTIVE_BATCHES = (4096,)
DTYPE = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_project(batch: int) -> str:
    spec = jax.ShapeDtypeStruct((batch, 3), DTYPE)
    return to_hlo_text(jax.jit(model.triplet_sweep).lower(spec, spec, spec))


def lower_pair(batch: int) -> str:
    s = jax.ShapeDtypeStruct((batch,), DTYPE)
    return to_hlo_text(jax.jit(model.pair_sweep).lower(s, s, s, s, s, s, s))


def lower_objective(batch: int) -> str:
    s = jax.ShapeDtypeStruct((batch,), DTYPE)
    return to_hlo_text(jax.jit(model.objective_terms).lower(s, s, s, s, s, s, s))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = []
    for b in PROJECT_BATCHES:
        path = os.path.join(args.out, f"project_b{b}.hlo.txt")
        text = lower_project(b)
        with open(path, "w") as fh:
            fh.write(text)
        manifest.append(f"project_b{b}: triplet_sweep (B={b},3) f32 -> (x3', y3')")
        print(f"wrote {path} ({len(text)} chars)")
    for b in PAIR_BATCHES:
        path = os.path.join(args.out, f"pair_b{b}.hlo.txt")
        text = lower_pair(b)
        with open(path, "w") as fh:
            fh.write(text)
        manifest.append(f"pair_b{b}: pair_sweep 7x(B={b},) f32 -> 5x(B,)")
        print(f"wrote {path} ({len(text)} chars)")
    for b in OBJECTIVE_BATCHES:
        path = os.path.join(args.out, f"objective_b{b}.hlo.txt")
        text = lower_objective(b)
        with open(path, "w") as fh:
            fh.write(text)
        manifest.append(f"objective_b{b}: objective_terms 7x(B={b},) f32 -> (4,)")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.txt"), "w") as fh:
        fh.write("\n".join(manifest) + "\n")


if __name__ == "__main__":
    main()
