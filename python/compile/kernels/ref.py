"""Pure-jnp oracle for the Pallas kernel — the correctness reference.

Implements exactly the math of `metric_project._project_kernel` (and of the
Rust scalar hot path in rust/src/solver/projection.rs) without Pallas, so
pytest can assert the kernel against it on every shape/dtype hypothesis
draws.
"""

import jax.numpy as jnp

from .metric_project import SIGNS


def project_triplets_ref(x3, winv3, y3):
    """Reference batched triplet projection; same signature as the kernel."""
    x = jnp.asarray(x3)
    w = jnp.asarray(winv3)
    y = jnp.asarray(y3)
    s_norm = jnp.sum(w, axis=-1, keepdims=True)
    ys = []
    for t, signs in enumerate(SIGNS):
        sv = jnp.asarray(signs, dtype=x.dtype)
        y_t = y[:, t : t + 1]
        x_c = x + y_t * sv * w
        delta = jnp.sum(x_c * sv, axis=-1, keepdims=True)
        theta = jnp.maximum(delta, 0.0) / s_norm
        x = x_c - theta * sv * w
        ys.append(theta[:, 0])
    return x, jnp.stack(ys, axis=-1)


def project_triplets_scalar(x3, winv3, y3):
    """Scalar (python-loop) port of the Rust solver's visit_metric, for
    triple-checking the vectorized math lane by lane. Slow; tests only."""
    import numpy as np

    x = np.array(x3, dtype=np.float64)
    w = np.array(winv3, dtype=np.float64)
    y = np.array(y3, dtype=np.float64)
    out_x = x.copy()
    out_y = y.copy()
    for lane in range(x.shape[0]):
        xv = out_x[lane]
        for t, signs in enumerate(SIGNS):
            yv = out_y[lane, t]
            # correction
            xc = xv + yv * np.array(signs) * w[lane]
            delta = float(np.dot(signs, xc))
            theta = max(delta, 0.0) / float(w[lane].sum())
            xv = xc - theta * np.array(signs) * w[lane]
            out_y[lane, t] = theta
        out_x[lane] = xv
    return out_x, out_y
