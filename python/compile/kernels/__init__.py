"""L1: Pallas kernels for the metric-projection hot-spot."""
