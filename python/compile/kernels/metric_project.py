"""Layer-1 Pallas kernel: batched Dykstra visit of a triplet's 3 metric
constraints.

One conflict-free *wave* of the Rust coordinator's schedule is a batch of
independent triplets: each lane owns the 3 variables (x_ij, x_ik, x_jk) and
the 3 scaled duals of its triplet, so the whole batch is data-parallel.
Per lane the kernel performs, sequentially for constraint types t = 0,1,2
(sign patterns s_t), the fused correction+projection of Algorithm 1:

    x_c   = x + y_t * s_t * winv          (correction)
    delta = <s_t, x_c>                     (violation; b = 0)
    theta = max(delta, 0) / sum(winv)      (a' W^{-1} a = sum(winv))
    x     = x_c - theta * s_t * winv       (projection)
    y_t   = theta                          (dual update)

Hardware adaptation (DESIGN.md §2): the paper's multicore cache tiling
becomes the HBM<->VMEM schedule expressed by the BlockSpec below — each
grid step streams one block of lanes through VMEM; the update itself is
element-wise VPU work (no MXU), so the kernel is memory-bound.

`interpret=True` is REQUIRED here: the CPU PJRT plugin cannot execute the
Mosaic custom-call that real TPU lowering emits (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sign patterns of the 3 metric constraints of an (i,j,k) triplet, in the
# same visit order as the Rust solver (solver/projection.rs METRIC_SIGNS).
SIGNS = ((1.0, -1.0, -1.0), (-1.0, 1.0, -1.0), (-1.0, -1.0, 1.0))

# Default lane block: 9 f32 arrays x 1024 lanes x 4 B = 36 KiB working set,
# comfortably inside a TPU core's ~16 MiB VMEM with double buffering.
DEFAULT_BLOCK = 1024


def _project_kernel(x_ref, w_ref, y_ref, xo_ref, yo_ref):
    """Pallas kernel body: one block of lanes, shape (block, 3).

    The sign patterns are unrolled as scalar +-1 factors (Pallas kernels
    may not capture constant arrays), keeping everything element-wise.
    """
    x0, x1, x2 = x_ref[:, 0], x_ref[:, 1], x_ref[:, 2]
    w0, w1, w2 = w_ref[:, 0], w_ref[:, 1], w_ref[:, 2]
    s_norm = w0 + w1 + w2  # a' W^{-1} a (signs square to 1)
    ys = []
    for t, (s0, s1, s2) in enumerate(SIGNS):
        y_t = y_ref[:, t]
        # correction
        c0 = x0 + y_t * s0 * w0
        c1 = x1 + y_t * s1 * w1
        c2 = x2 + y_t * s2 * w2
        delta = s0 * c0 + s1 * c1 + s2 * c2
        theta = jnp.maximum(delta, 0.0) / s_norm
        # projection
        x0 = c0 - theta * s0 * w0
        x1 = c1 - theta * s1 * w1
        x2 = c2 - theta * s2 * w2
        ys.append(theta)
    xo_ref[:, 0], xo_ref[:, 1], xo_ref[:, 2] = x0, x1, x2
    yo_ref[:, 0], yo_ref[:, 1], yo_ref[:, 2] = ys[0], ys[1], ys[2]


@functools.partial(jax.jit, static_argnames=("block",))
def project_triplets(x3, winv3, y3, *, block=DEFAULT_BLOCK):
    """Batched triplet projection via the Pallas kernel.

    Args:
      x3:    (B, 3) distances (x_ij, x_ik, x_jk) per lane.
      winv3: (B, 3) inverse weights per lane.
      y3:    (B, 3) scaled duals from the previous pass, per constraint type.
      block: lane block size (B must be a multiple, callers pad).

    Returns:
      (x3', y3'): updated distances and duals.
    """
    b_total, three = x3.shape
    assert three == 3, f"expected (B, 3), got {x3.shape}"
    assert b_total % block == 0, f"B={b_total} not a multiple of block={block}"
    grid = (b_total // block,)
    spec = pl.BlockSpec((block, 3), lambda i: (i, 0))
    return pl.pallas_call(
        _project_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(x3.shape, x3.dtype),
            jax.ShapeDtypeStruct(y3.shape, y3.dtype),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x3, winv3, y3)
