"""L2 correctness: pair_sweep and objective_terms vs scalar math mirroring
rust/src/solver/{projection,termination}.rs."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

jax.config.update("jax_enable_x64", True)


def pair_scalar(x, f, winv, d, yu, yl, yb):
    """Scalar port of visit_pair_upper/lower + visit_box_upper."""
    # upper
    delta = x - f - d + 2 * yu * winv
    theta = max(delta, 0.0) / (2 * winv)
    c = yu - theta
    x, f, yu = x + c * winv, f - c * winv, theta
    # lower
    delta = d - x - f + 2 * yl * winv
    theta = max(delta, 0.0) / (2 * winv)
    c = yl - theta
    x, f, yl = x - c * winv, f - c * winv, theta
    # box
    delta = x + yb * winv - 1.0
    theta = max(delta, 0.0) / winv
    c = yb - theta
    x, yb = x + c * winv, theta
    return x, f, yu, yl, yb


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1), b=st.integers(1, 64))
def test_pair_sweep_matches_scalar(seed, b):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 2, b)
    f = rng.uniform(-1, 2, b)
    winv = rng.uniform(0.3, 3.0, b)
    d = rng.integers(0, 2, b).astype(float)
    yu = rng.uniform(0, 0.5, b)
    yl = rng.uniform(0, 0.5, b)
    yb = rng.uniform(0, 0.5, b)
    got = model.pair_sweep(x, f, winv, d, yu, yl, yb)
    for lane in range(b):
        want = pair_scalar(x[lane], f[lane], winv[lane], d[lane], yu[lane], yl[lane], yb[lane])
        for gi, wi in zip(got, want):
            np.testing.assert_allclose(np.array(gi)[lane], wi, atol=1e-12)


def test_pair_sweep_feasible_fixed_point():
    # x between d-f and d+f, x <= 1, zero duals: nothing moves.
    x = np.array([0.5, 0.2])
    f = np.array([1.0, 1.0])
    winv = np.array([1.0, 2.0])
    d = np.array([0.0, 1.0])
    z = np.zeros(2)
    nx, nf, yu, yl, yb = model.pair_sweep(x, f, winv, d, z, z, z)
    np.testing.assert_allclose(nx, x, atol=1e-12)
    np.testing.assert_allclose(nf, f, atol=1e-12)
    assert np.allclose(yu, 0) and np.allclose(yl, 0) and np.allclose(yb, 0)


def test_objective_terms_formulas():
    rng = np.random.default_rng(1)
    b = 100
    x = rng.uniform(0, 1, b)
    f = rng.uniform(0, 1, b)
    w = rng.uniform(0.5, 2, b)
    d = rng.integers(0, 2, b).astype(float)
    yu = rng.uniform(0, 1, b)
    yl = rng.uniform(0, 1, b)
    yb = rng.uniform(0, 1, b)
    out = np.array(model.objective_terms(x, f, w, d, yu, yl, yb))
    np.testing.assert_allclose(out[0], (w * f).sum(), rtol=1e-12)
    np.testing.assert_allclose(out[1], (w * (x**2 + f**2)).sum(), rtol=1e-12)
    np.testing.assert_allclose(out[2], (d * (yu - yl) + yb).sum(), rtol=1e-12)
    np.testing.assert_allclose(out[3], (w * np.abs(x - d)).sum(), rtol=1e-12)


@pytest.mark.parametrize("b", [1024, 4096])
def test_triplet_sweep_shapes(b):
    x = np.zeros((b, 3), np.float32)
    w = np.ones((b, 3), np.float32)
    y = np.zeros((b, 3), np.float32)
    ox, oy = model.triplet_sweep(x, w, y)
    assert ox.shape == (b, 3) and oy.shape == (b, 3)
