"""L1 correctness: Pallas kernel vs the pure-jnp oracle (and a scalar port
of the Rust hot path). This is the core cross-layer correctness signal:
rust/src/solver/projection.rs, the Pallas kernel, and ref.py must agree.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.metric_project import SIGNS, project_triplets
from compile.kernels.ref import project_triplets_ref, project_triplets_scalar

jax.config.update("jax_enable_x64", True)


def rand_inputs(rng, b, dtype=np.float64, y_scale=0.5):
    x = rng.uniform(-1.0, 2.0, size=(b, 3)).astype(dtype)
    w = rng.uniform(0.4, 2.5, size=(b, 3)).astype(dtype)
    y = (rng.uniform(0.0, y_scale, size=(b, 3)) * rng.integers(0, 2, size=(b, 3))).astype(dtype)
    return x, w, y


@pytest.mark.parametrize("b", [1, 2, 7, 64, 1024, 2048])
def test_kernel_matches_ref(b):
    rng = np.random.default_rng(b)
    x, w, y = rand_inputs(rng, b)
    block = min(1024, b) if b % min(1024, b) == 0 else 1
    kx, ky = project_triplets(x, w, y, block=block)
    rx, ry = project_triplets_ref(x, w, y)
    np.testing.assert_allclose(kx, rx, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(ky, ry, rtol=1e-12, atol=1e-12)


def test_ref_matches_scalar_rust_port():
    rng = np.random.default_rng(0)
    x, w, y = rand_inputs(rng, 50)
    rx, ry = project_triplets_ref(x, w, y)
    sx, sy = project_triplets_scalar(x, w, y)
    np.testing.assert_allclose(rx, sx, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(ry, sy, rtol=1e-12, atol=1e-12)


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=96),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    dtype=st.sampled_from([np.float32, np.float64]),
)
def test_kernel_matches_ref_hypothesis(b, seed, dtype):
    rng = np.random.default_rng(seed)
    x, w, y = rand_inputs(rng, b, dtype=dtype)
    kx, ky = project_triplets(x, w, y, block=1)
    rx, ry = project_triplets_ref(x, w, y)
    tol = 1e-5 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(kx, rx, rtol=tol, atol=tol)
    np.testing.assert_allclose(ky, ry, rtol=tol, atol=tol)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_projection_invariants(seed):
    """After a visit with zero incoming duals, each constraint t is
    satisfied at its own projection point, duals are nonnegative, and
    satisfied-with-zero-dual lanes are untouched."""
    rng = np.random.default_rng(seed)
    x, w, _ = rand_inputs(rng, 64)
    y0 = np.zeros_like(x)
    kx, ky = project_triplets(x, w, y0, block=64)
    kx, ky = np.array(kx), np.array(ky)
    assert (ky >= 0.0).all()
    # After the full sweep the LAST constraint (t=2) is exactly satisfied.
    s2 = np.array(SIGNS[2])
    delta2 = (kx * s2).sum(axis=-1)
    assert (delta2 <= 1e-9).all()
    # Lanes already metric with no duals are fixed points.
    metric_mask = np.ones(len(x), dtype=bool)
    for s in SIGNS:
        metric_mask &= (x * np.array(s)).sum(axis=-1) <= 0.0
    np.testing.assert_allclose(kx[metric_mask], x[metric_mask], atol=1e-12)
    assert np.allclose(ky[metric_mask], 0.0)


def test_repeated_sweeps_converge_to_metric():
    """Iterating the kernel (Dykstra on a single triplet per lane) must
    converge: all 3 constraints satisfied in the limit."""
    rng = np.random.default_rng(3)
    x, w, y = rand_inputs(rng, 32, y_scale=0.0)
    for _ in range(200):
        x, y = project_triplets(x, w, y, block=32)
    x = np.array(x)
    for s in SIGNS:
        assert ((x * np.array(s)).sum(axis=-1) <= 1e-8).all()


def test_block_size_does_not_change_result():
    rng = np.random.default_rng(9)
    x, w, y = rand_inputs(rng, 2048)
    a = project_triplets(x, w, y, block=1024)
    b = project_triplets(x, w, y, block=256)
    c = project_triplets(x, w, y, block=2048)
    np.testing.assert_allclose(a[0], b[0], atol=1e-12)
    np.testing.assert_allclose(a[0], c[0], atol=1e-12)
    np.testing.assert_allclose(a[1], b[1], atol=1e-12)


def test_paper_worked_example():
    """§II-B(c): x_ij=3, x_ik=1, x_jk=1, unit weights -> delta=1,
    update x_ij -= 1/3, x_ik += 1/3, x_jk += 1/3."""
    x = np.array([[3.0, 1.0, 1.0]])
    w = np.ones((1, 3))
    y = np.zeros((1, 3))
    kx, ky = project_triplets(x, w, y, block=1)
    kx = np.array(kx)
    # first constraint projects to (3-1/3, 1+1/3, 1+1/3); t=1 and t=2 are
    # then satisfied, so that's the final state.
    np.testing.assert_allclose(kx, [[3 - 1 / 3, 1 + 1 / 3, 1 + 1 / 3]], atol=1e-12)
    np.testing.assert_allclose(np.array(ky)[0, 0], 1 / 3, atol=1e-12)
    assert np.array(ky)[0, 1] == 0.0 and np.array(ky)[0, 2] == 0.0
