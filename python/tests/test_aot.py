"""AOT path coverage: every artifact the Makefile builds must lower to
valid, parameter-correct HLO text that the Rust runtime's parser accepts
(structurally: an ENTRY computation with the expected parameter count and
f32 shapes)."""

import re

import pytest

from compile import aot


def entry_params(text):
    """Count parameters of the ENTRY computation only (HLO text nests
    helper computations that have their own parameters)."""
    entry = text[text.index("ENTRY") :]
    return len(re.findall(r"parameter\(\d+\)", entry))


@pytest.mark.parametrize("batch", [1024, 4096])
def test_project_lowering_shape(batch):
    text = aot.lower_project(batch)
    assert "ENTRY" in text
    # 3 inputs of shape (batch, 3) f32
    assert entry_params(text) == 3
    assert f"f32[{batch},3]" in text
    # tupled 2-output
    assert re.search(r"ROOT.*tuple", text)


def test_pair_lowering_shape():
    text = aot.lower_pair(4096)
    assert entry_params(text) == 7
    assert "f32[4096]" in text


def test_objective_lowering_shape():
    text = aot.lower_objective(4096)
    assert entry_params(text) == 7
    assert "f32[4]" in text  # stacked output


def test_lowering_is_deterministic():
    assert aot.lower_objective(4096) == aot.lower_objective(4096)


def test_pallas_kernel_lowers_into_hlo():
    # The project artifact must contain the kernel's arithmetic inline
    # (interpret=True lowers to plain HLO: no custom-call op).
    text = aot.lower_project(1024)
    assert "custom-call" not in text, "Mosaic custom-call cannot run on CPU PJRT"
    assert "divide" in text or "multiply" in text
