//! Project-and-forget in action: the same correlation-clustering LP
//! solved with the paper's full sweeps and with the active-set strategy
//! (`solver::active`), comparing constraint visits against solution
//! quality. After the first few passes only a small fraction of the
//! `3·C(n,3)` metric rows stay active, so the active run reaches the same
//! optimum with a fraction of the visits.
//!
//!     cargo run --release --example active_set [n]

use metric_proj::instance::CcLpInstance;
use metric_proj::solver::{dykstra_parallel, SolveOpts, Strategy};
use metric_proj::util::parallel::available_cores;
use metric_proj::util::timer::time;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let inst = CcLpInstance::random(n, 0.5, 0.8, 1.6, 42);
    println!(
        "random CC-LP: n={n}, {:.2e} metric constraints",
        inst.n_metric_constraints() as f64
    );

    let base = SolveOpts {
        max_passes: 300,
        check_every: 10,
        tol_violation: 1e-7,
        tol_gap: 1e30, // violation-driven stop for a clean work comparison
        threads: available_cores(),
        tile: 20,
        ..Default::default()
    };

    let mut totals = Vec::new();
    for (label, strategy) in [
        ("full", Strategy::Full),
        ("active(8,3)", Strategy::Active { sweep_every: 8, forget_after: 3 }),
    ] {
        let (sol, secs) = time(|| dykstra_parallel::solve(&inst, &SolveOpts { strategy, ..base }));
        println!(
            "{label:<12} passes={:<4} visits={:.3e} active={:<8} viol={:.2e} lp={:.4} time={secs:.2}s",
            sol.passes,
            sol.metric_visits as f64,
            sol.active_triplets,
            sol.residuals.max_violation,
            sol.residuals.lp_objective,
        );
        totals.push(sol.metric_visits);
    }
    if let [full, active] = totals[..] {
        println!(
            "\nactive performed {:.1}% of the full solver's constraint visits",
            100.0 * active as f64 / full.max(1) as f64
        );
    }
}
