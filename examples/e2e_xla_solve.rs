//! END-TO-END driver: proves all three layers compose on a real workload.
//!
//! Pipeline: generate a paper-style graph (ca-GrQc analogue, LCC) ->
//! §IV-B Jaccard construction -> solve the metric-constrained LP TWICE:
//!
//!   1. CPU engine  — the paper's parallel Dykstra (L3 only, f64), and
//!   2. XLA engine  — the same Dykstra driven through the AOT-compiled
//!      JAX+Pallas kernels (`artifacts/*.hlo.txt`, built once by
//!      `make artifacts`) via PJRT: L3 gathers conflict-free batches,
//!      L2/L1 executes the projection math, L3 scatters back.
//!
//! Reports agreement of the two optima, constraint satisfaction, LP
//! objective, rounded clustering quality, and per-engine throughput
//! (constraint visits/second) — the numbers recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example e2e_xla_solve [n]

use metric_proj::graph::datasets::Dataset;
use metric_proj::instance::cc_objective;
use metric_proj::instance::construction::{build_cc_instance, ConstructionParams};
use metric_proj::rounding::pivot;
use metric_proj::runtime::engine::XlaEngine;
use metric_proj::solver::{dykstra_parallel, dykstra_xla, SolveOpts};
use metric_proj::util::timer::time;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(60);
    let passes = 150;

    // --- workload -------------------------------------------------------
    let g = Dataset::CaGrQc.load_or_generate(std::path::Path::new("data"), n, 42);
    let inst = build_cc_instance(&g, ConstructionParams::default(), 2);
    let visits_per_pass = inst.n_metric_constraints() as f64;
    println!("workload : ca-GrQc analogue, n={}, m={}", g.n(), g.m());
    println!("          {:.2e} metric constraints/pass, {passes} passes", visits_per_pass);

    // --- L1/L2 artifacts through PJRT ------------------------------------
    let engine = XlaEngine::load("artifacts").map_err(|e| {
        anyhow::anyhow!("{e}\nhint: run `make artifacts` first to build the HLO artifacts")
    })?;
    println!("pjrt     : platform = {}", engine.platform());

    // --- solve with both engines -----------------------------------------
    let opts = SolveOpts { max_passes: passes, threads: 2, tile: 16, ..Default::default() };
    let (cpu, t_cpu) = time(|| dykstra_parallel::solve(&inst, &opts));
    let (xla, t_xla) = time(|| dykstra_xla::solve(&inst, &opts, &engine));
    let xla = xla?;

    println!("\n== CPU engine (scalar f64, wave schedule) ==");
    println!("time      : {t_cpu:.2}s  ({:.2e} visits/s)", passes as f64 * visits_per_pass / t_cpu);
    println!("violation : {:.2e}", cpu.residuals.max_violation);
    println!("LP obj    : {:.4}", cpu.residuals.lp_objective);

    println!("\n== XLA engine (Pallas kernel via PJRT, delta batches) ==");
    println!("time      : {t_xla:.2}s  ({:.2e} visits/s)", passes as f64 * visits_per_pass / t_xla);
    println!("violation : {:.2e}", xla.residuals.max_violation);
    println!("LP obj    : {:.4}", xla.residuals.lp_objective);

    // --- cross-engine agreement ------------------------------------------
    let mut worst: f64 = 0.0;
    for (i, j, v) in xla.x.iter_pairs() {
        worst = worst.max((v - cpu.x.get(i, j)).abs());
    }
    println!("\nmax |x_xla - x_cpu| = {worst:.2e} (f32 artifacts vs f64 scalar)");
    anyhow::ensure!(worst < 5e-2, "engines disagree beyond f32 tolerance: {worst}");
    anyhow::ensure!(
        (xla.residuals.lp_objective - cpu.residuals.lp_objective).abs()
            < 1e-2 * cpu.residuals.lp_objective.max(1.0),
        "LP objectives diverged"
    );

    // --- downstream clustering -------------------------------------------
    let (labels, obj) = pivot::round_best(&xla.x, 20, 3, |l| cc_objective(&inst, l));
    let k = labels.iter().max().unwrap() + 1;
    println!(
        "rounded clustering (from XLA solution): {k} clusters, obj {obj:.4}, ratio vs LP {:.3}",
        obj / xla.residuals.lp_objective.max(1e-12)
    );
    println!("\nE2E OK: graph -> instance -> L3 coordinator -> PJRT(L2/L1) -> LP -> clustering");
    Ok(())
}
