//! Metric nearness (paper (1), Sra-Tropp-Dhillon [36]): repair a noisy
//! dissimilarity matrix into the nearest metric in the weighted l2 sense,
//! using the parallel projection schedule.
//!
//!     cargo run --release --example metric_nearness [n]

use metric_proj::instance::metric_nearness::{max_triangle_violation, MetricNearnessInstance};
use metric_proj::matrix::PackedSym;
use metric_proj::solver::nearness::{solve, solve_serial_order, NearnessOpts};
use metric_proj::util::rng::Rng;
use metric_proj::util::timer::time;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(150);

    // Ground truth: points on a line -> |pos_i - pos_j| is a metric.
    // Corrupt it with multiplicative noise; the result usually is not.
    let mut rng = Rng::new(7);
    let pos: Vec<f64> = (0..n).map(|_| rng.f64_in(0.0, 10.0)).collect();
    let clean = PackedSym::from_fn(n, |i, j| (pos[i] - pos[j]).abs());
    let noisy = PackedSym::from_fn(n, |i, j| {
        (pos[i] - pos[j]).abs() * rng.f64_in(0.6, 1.6) + rng.f64_in(0.0, 0.3)
    });
    println!(
        "n = {n}: clean violation {:.2e}, noisy violation {:.2e}",
        max_triangle_violation(&clean).max(0.0),
        max_triangle_violation(&noisy)
    );

    let inst = MetricNearnessInstance::new(noisy.clone());
    let opts = NearnessOpts {
        max_passes: 300,
        check_every: 10,
        tol_violation: 1e-7,
        threads: 4,
        tile: 16,
        ..Default::default()
    };

    let (par, t_par) = time(|| solve(&inst, &opts));
    println!("\nparallel schedule : {} passes in {t_par:.2}s", par.passes);
    println!("  ||X - D||_W^2   = {:.4}", par.objective);
    println!("  max violation   = {:.2e}", par.max_violation);

    let (ser, t_ser) = time(|| solve_serial_order(&inst, &opts));
    println!("serial order [36] : {} passes in {t_ser:.2}s", ser.passes);
    println!("  ||X - D||_W^2   = {:.4}", ser.objective);

    // Both orders converge to the same unique projection.
    let mut worst: f64 = 0.0;
    for (i, j, v) in par.x.iter_pairs() {
        worst = worst.max((v - ser.x.get(i, j)).abs());
    }
    println!("max |x_par - x_ser| = {worst:.2e}");

    // Repaired matrix should be closer to the clean metric than the noisy
    // input was (denoising effect of the metric projection).
    let dist = |a: &PackedSym, b: &PackedSym| {
        a.sub(b).as_slice().iter().map(|v| v * v).sum::<f64>().sqrt()
    };
    println!("\n||noisy - clean||_F    = {:.3}", dist(&noisy, &clean));
    println!("||repaired - clean||_F = {:.3}", dist(&par.x, &clean));
}
