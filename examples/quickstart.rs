//! Quickstart: build a small correlation-clustering LP, solve it with the
//! parallel projection method, and round to a clustering.
//!
//!     cargo run --release --example quickstart

use metric_proj::graph::generators::two_cliques;
use metric_proj::instance::cc_objective;
use metric_proj::instance::construction::{build_cc_instance, ConstructionParams};
use metric_proj::rounding::{pivot, threshold};
use metric_proj::solver::{dykstra_parallel, SolveOpts};

fn main() {
    // 1. A graph with obvious structure: two 12-cliques joined by a bridge.
    let g = two_cliques(12);
    println!("graph: {} nodes, {} edges (two cliques + bridge)", g.n(), g.m());

    // 2. The §IV-B construction: Jaccard similarity -> signed dense instance.
    let params = ConstructionParams { threshold: 0.1, epsilon: 0.01 };
    let inst = build_cc_instance(&g, params, 2);
    println!(
        "instance: {} pairs, {:.2e} constraints",
        inst.w.len(),
        inst.n_constraints() as f64
    );

    // 3. Solve the metric-constrained LP relaxation with parallel Dykstra.
    let opts = SolveOpts {
        max_passes: 200,
        check_every: 10,
        tol_violation: 1e-5,
        tol_gap: 1e-4,
        threads: 4,
        tile: 8,
        ..Default::default()
    };
    let sol = dykstra_parallel::solve(&inst, &opts);
    println!(
        "solved in {} passes: violation {:.2e}, rel gap {:.2e}",
        sol.passes, sol.residuals.max_violation, sol.residuals.rel_gap
    );
    println!("LP objective (lower bound on any clustering): {:.4}", sol.residuals.lp_objective);

    // 4. Round the fractional solution two ways.
    let labels_thresh = threshold::round(&sol.x, 0.5);
    let (labels_pivot, _) = pivot::round_best(&sol.x, 10, 1, |l| cc_objective(&inst, l));
    let k = |l: &[usize]| l.iter().max().unwrap() + 1;
    println!(
        "threshold rounding: {} clusters, CC objective {:.4}",
        k(&labels_thresh),
        cc_objective(&inst, &labels_thresh)
    );
    println!(
        "pivot rounding    : {} clusters, CC objective {:.4}",
        k(&labels_pivot),
        cc_objective(&inst, &labels_pivot)
    );

    // 5. The two cliques should be recovered.
    let first = labels_thresh[0];
    let second = labels_thresh[12];
    let ok = (0..12).all(|u| labels_thresh[u] == first)
        && (12..24).all(|u| labels_thresh[u] == second)
        && first != second;
    println!("clique recovery: {}", if ok { "EXACT" } else { "inexact (see objectives)" });
}
