//! Full correlation-clustering pipeline on a paper-style dataset: generate
//! the ca-GrQc analogue, apply the §IV-B construction, solve the LP
//! relaxation with both the serial baseline and the parallel method,
//! compare their convergence, and round to clusterings with quality
//! certified against the LP lower bound.
//!
//!     cargo run --release --example correlation_clustering [n]

use metric_proj::graph::datasets::Dataset;
use metric_proj::instance::cc_objective;
use metric_proj::instance::construction::{build_cc_instance, ConstructionParams};
use metric_proj::rounding::{pivot, threshold};
use metric_proj::solver::{dykstra_parallel, dykstra_serial, SolveOpts};
use metric_proj::util::timer::time;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(120);

    // Dataset: synthetic analogue of SNAP ca-GrQc (largest component).
    let g = Dataset::CaGrQc.load_or_generate(std::path::Path::new("data"), n, 42);
    println!("ca-GrQc analogue: n={} m={} (paper n=4158)", g.n(), g.m());
    let inst = build_cc_instance(&g, ConstructionParams::default(), 2);
    let n_neg = inst.d.as_slice().iter().filter(|&&d| d == 1.0).count();
    println!(
        "instance: {} pairs ({} negative), {:.2e} metric constraints",
        inst.w.len(),
        n_neg,
        inst.n_metric_constraints() as f64
    );

    // Solve with the serial baseline [37] and the paper's parallel method.
    let passes = 120;
    let (ser, t_ser) = time(|| {
        dykstra_serial::solve(&inst, &SolveOpts { max_passes: passes, ..Default::default() })
    });
    let (par, t_par) = time(|| {
        dykstra_parallel::solve(
            &inst,
            &SolveOpts { max_passes: passes, threads: 4, tile: 20, ..Default::default() },
        )
    });
    println!(
        "\nserial  [37]: {t_ser:.2}s, violation {:.2e}, LP obj {:.4}",
        ser.residuals.max_violation, ser.residuals.lp_objective
    );
    println!(
        "parallel    : {t_par:.2}s, violation {:.2e}, LP obj {:.4}",
        par.residuals.max_violation, par.residuals.lp_objective
    );
    let mut worst: f64 = 0.0;
    for (i, j, v) in par.x.iter_pairs() {
        worst = worst.max((v - ser.x.get(i, j)).abs());
    }
    println!("max |x_par - x_ser| = {worst:.2e} (same unique optimum)");

    // Round and certify.
    let lp = par.residuals.lp_objective;
    let labels_t = threshold::round(&par.x, 0.5);
    let obj_t = cc_objective(&inst, &labels_t);
    let (labels_p, obj_p) = pivot::round_best(&par.x, 50, 7, |l| cc_objective(&inst, l));
    let k = |l: &[usize]| l.iter().max().unwrap() + 1;
    println!("\nLP lower bound        : {lp:.4}");
    println!(
        "threshold rounding    : obj {obj_t:.4} ({} clusters) -> ratio {:.3}",
        k(&labels_t),
        obj_t / lp
    );
    println!(
        "pivot rounding (best) : obj {obj_p:.4} ({} clusters) -> ratio {:.3}",
        k(&labels_p),
        obj_p / lp
    );
    // The LP certifies near-optimality: any clustering costs >= lp.
    assert!(obj_t >= lp - 1e-6 && obj_p >= lp - 1e-6, "LP bound violated?!");
}
